//! Report rendering: markdown emitters for every experiment, matching the
//! rows/series the paper's tables and figures show.

use crate::coordinator::experiments::{EsStudy, Table1Row, TradeoffPoint};

/// Render Table 1 exactly in the paper's column layout.
pub fn render_table1(rows: &[Table1Row]) -> String {
    let mut s = String::new();
    s.push_str(
        "| Dataset | Inference Size | Posit Acc. (es) | Float Acc. (w_e) | Fixed Acc. (Q) | 64-bit Float Acc. |\n",
    );
    s.push_str("|---|---|---|---|---|---|\n");
    for r in rows {
        let accs = [r.posit.0, r.float.0, r.fixed.0];
        let hi = accs.into_iter().fold(0.0f64, f64::max);
        // Bold only a UNIQUE winner: on an exact accuracy tie no format
        // "won" the row, and bolding all of them read as three winners.
        let winners = accs.iter().filter(|&&a| (a - hi).abs() < 1e-12).count();
        let cell = |acc: f64, p: u32| {
            if winners == 1 && (acc - hi).abs() < 1e-12 {
                format!("**{:.1}%** ({p})", acc * 100.0)
            } else {
                format!("{:.1}% ({p})", acc * 100.0)
            }
        };
        s.push_str(&format!(
            "| {} | {} | {} | {} | {} | {:.1}% |\n",
            r.dataset,
            r.inference_size,
            cell(r.posit.0, r.posit.1),
            cell(r.float.0, r.float.1),
            cell(r.fixed.0, r.fixed.1),
            r.baseline * 100.0,
        ));
    }
    s
}

/// Render the Fig. 6 series (degradation vs EDP) as a markdown table plus an
/// ASCII scatter for terminal viewing.
pub fn render_tradeoff(points: &[TradeoffPoint], metric: &str) -> String {
    let metric_of = |p: &TradeoffPoint| -> f64 {
        match metric {
            "edp" => p.edp_pj_ns,
            "delay" => p.delay_ns,
            "power" => p.power_mw,
            _ => panic!("unknown metric {metric}"),
        }
    };
    let unit = match metric {
        "edp" => "pJ·ns",
        "delay" => "ns",
        _ => "mW",
    };
    let mut s = format!("| config | bits | avg degradation | {metric} ({unit}) | ★ |\n|---|---|---|---|---|\n");
    for p in points {
        s.push_str(&format!(
            "| {} | {} | {:+.2}% | {:.2} | {} |\n",
            p.spec.name(),
            p.spec.n(),
            p.avg_degradation * 100.0,
            metric_of(p),
            if p.star { "★" } else { "" }
        ));
    }
    s.push('\n');
    s.push_str(&ascii_scatter(points, &metric_of, metric));
    s
}

/// Minimal log-x ASCII scatter: rows = points sorted by metric.
fn ascii_scatter(points: &[TradeoffPoint], metric_of: &dyn Fn(&TradeoffPoint) -> f64, label: &str) -> String {
    let (lo, hi) = points.iter().fold((f64::INFINITY, 0.0f64), |(lo, hi), p| {
        (lo.min(metric_of(p)), hi.max(metric_of(p)))
    });
    let degs: Vec<f64> = points.iter().map(|p| p.avg_degradation).collect();
    let (dlo, dhi) = crate::util::stats::min_max(&degs);
    let width = 48usize;
    let mut s = format!("degradation (rows) vs {label} (column position, log scale)\n");
    let mut sorted: Vec<&TradeoffPoint> = points.iter().collect();
    sorted.sort_by(|a, b| a.avg_degradation.partial_cmp(&b.avg_degradation).unwrap());
    for p in sorted {
        let x = if hi > lo {
            ((metric_of(p).ln() - lo.ln()) / (hi.ln() - lo.ln()) * (width as f64 - 1.0)) as usize
        } else {
            0
        };
        let mut line = vec![b' '; width];
        line[x.min(width - 1)] = match p.spec.family() {
            "posit" => b'P',
            "float" => b'F',
            _ => b'X',
        };
        let deg_bar = if dhi > dlo { (p.avg_degradation - dlo) / (dhi - dlo) } else { 0.0 };
        s.push_str(&format!(
            "{:>12} {:>6.2}% |{}| {}\n",
            p.spec.name(),
            p.avg_degradation * 100.0,
            String::from_utf8(line).unwrap(),
            "#".repeat((deg_bar * 10.0) as usize)
        ));
    }
    s
}

/// Render the §5.1 es study.
pub fn render_es_study(s: &EsStudy) -> String {
    format!(
        "posit es parameter study (§5.1)\n\n\
         | es | avg accuracy [5,7]-bit | EDP ratio vs es=0 (n=8) |\n|---|---|---|\n\
         | 0 | {:.1}% | {:.2}× |\n| 1 | {:.1}% | {:.2}× |\n| 2 | {:.1}% | {:.2}× |\n\n\
         paper: EDP(es=1) ≈ 1.4×, EDP(es=2) ≈ 3×; accuracy(es=1) best for [5,7]-bit.\n",
        s.avg_acc[0] * 100.0,
        s.edp_ratio[0],
        s.avg_acc[1] * 100.0,
        s.edp_ratio[1],
        s.avg_acc[2] * 100.0,
        s.edp_ratio[2],
    )
}

/// Render Table 2 (posit-hardware comparison).
pub fn render_table2() -> String {
    let rows = crate::hw::table2_rows();
    let mut s = String::new();
    for (i, row) in rows.iter().enumerate() {
        s.push_str(&format!("| {} |\n", row.join(" | ")));
        if i == 0 {
            s.push_str(&format!("|{}\n", "---|".repeat(row.len())));
        }
    }
    s
}

/// Write a report file under results/ (created on demand).
pub fn write_report(name: &str, content: &str) -> std::io::Result<std::path::PathBuf> {
    let dir = std::path::PathBuf::from("results");
    std::fs::create_dir_all(&dir)?;
    let path = dir.join(name);
    std::fs::write(&path, content)?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::FormatSpec;

    #[test]
    fn table1_renders_and_bolds_best() {
        let rows = vec![Table1Row {
            dataset: "iris".into(),
            inference_size: 50,
            posit: (0.98, 1),
            float: (0.96, 3),
            fixed: (0.92, 4),
            baseline: 0.98,
        }];
        let s = render_table1(&rows);
        assert!(s.contains("**98.0%** (1)"));
        assert!(s.contains("| iris | 50 |"));
    }

    #[test]
    fn table1_does_not_bold_on_exact_ties() {
        // Two families tie for the row maximum: NO cell may be bolded (a
        // tie has no unique winner). The old renderer bolded every format
        // within 1e-12 of the max, i.e. all tied cells.
        let rows = vec![Table1Row {
            dataset: "wdbc".into(),
            inference_size: 190,
            posit: (0.95, 1),
            float: (0.95, 4),
            fixed: (0.90, 5),
            baseline: 0.96,
        }];
        let s = render_table1(&rows);
        assert!(!s.contains("**"), "tied row must not bold any cell: {s}");
        assert!(s.contains("95.0% (1)") && s.contains("95.0% (4)") && s.contains("90.0% (5)"));
        // A unique winner still gets bolded.
        let rows = vec![Table1Row {
            dataset: "wdbc".into(),
            inference_size: 190,
            posit: (0.95, 1),
            float: (0.94, 4),
            fixed: (0.90, 5),
            baseline: 0.96,
        }];
        assert!(render_table1(&rows).contains("**95.0%** (1)"));
    }

    #[test]
    fn tradeoff_renders_scatter() {
        let points = vec![
            TradeoffPoint {
                spec: FormatSpec::Posit { n: 8, es: 1 },
                avg_degradation: 0.01,
                edp_pj_ns: 10.0,
                delay_ns: 5.0,
                power_mw: 2.0,
                star: true,
            },
            TradeoffPoint {
                spec: FormatSpec::Fixed { n: 8, q: 4 },
                avg_degradation: 0.70,
                edp_pj_ns: 2.0,
                delay_ns: 1.0,
                power_mw: 1.0,
                star: false,
            },
        ];
        let s = render_tradeoff(&points, "edp");
        assert!(s.contains("★"));
        assert!(s.contains("P") && s.contains("X"));
    }

    #[test]
    fn es_study_renders() {
        let s = render_es_study(&EsStudy { avg_acc: [0.9, 0.93, 0.91], edp_ratio: [1.0, 1.4, 3.0] });
        assert!(s.contains("1.40×") && s.contains("93.0%"));
    }

    #[test]
    fn table2_contains_this_work() {
        assert!(render_table2().contains("This Work"));
    }
}
