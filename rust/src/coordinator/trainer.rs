//! Training-loop driver over the PJRT train-step artifact: the Rust
//! coordinator owns the loop (shuffling, batching, loss logging,
//! early-stopping); XLA owns the math. This is the paper's "networks trained
//! with 32-bit floating point" baseline running on the three-layer stack.

use std::time::Instant;

use anyhow::Result;

use crate::datasets::Dataset;
use crate::runtime::{Runtime, TrainState};
use crate::util::Rng;

/// Hyperparameters for the PJRT training loop.
#[derive(Debug, Clone)]
pub struct LoopConfig {
    /// Passes over the training split.
    pub epochs: usize,
    /// Learning rate.
    pub lr: f64,
    /// SGD momentum coefficient.
    pub momentum: f64,
    /// Shuffling/init seed.
    pub seed: u64,
    /// Log the loss every N steps (0 = per epoch only).
    pub log_every: usize,
}

impl Default for LoopConfig {
    fn default() -> Self {
        LoopConfig { epochs: 10, lr: 0.05, momentum: 0.9, seed: 7, log_every: 0 }
    }
}

/// The training record (the e2e example's loss curve).
#[derive(Debug, Clone, Default)]
pub struct TrainLog {
    /// (global step, loss) samples.
    pub losses: Vec<(usize, f64)>,
    /// Mean loss per epoch.
    pub epoch_loss: Vec<f64>,
    /// Total optimizer steps taken.
    pub steps: usize,
    /// Training wall-clock, seconds.
    pub wall_seconds: f64,
}

impl TrainLog {
    /// Render the per-epoch loss table.
    pub fn render(&self) -> String {
        let mut s = String::from("epoch | mean loss\n------|----------\n");
        for (e, l) in self.epoch_loss.iter().enumerate() {
            s.push_str(&format!("{:>5} | {l:.4}\n", e + 1));
        }
        s.push_str(&format!("({} steps, {:.1}s wall)\n", self.steps, self.wall_seconds));
        s
    }
}

/// Run the training loop for `ds` through the dataset's train-step artifact.
/// Batches are z-scored on the fly; on completion the normalization is
/// folded into the first layer so the returned state consumes RAW features
/// (the network Deep Positron quantizes — see experiments::train_model).
pub fn train_via_pjrt(rt: &Runtime, ds: &Dataset, cfg: &LoopConfig) -> Result<(TrainState, TrainLog)> {
    let step_exe = rt.train_step(&ds.name)?;
    let batch = step_exe.batch();
    let dims = step_exe.dims().to_vec();
    assert_eq!(dims[0], ds.num_features, "artifact/topology mismatch");
    let classes = *dims.last().unwrap();
    let normalize = crate::datasets::normalizes_for_training(&ds.name);
    let (means, stds) = if normalize {
        ds.feature_stats()
    } else {
        (vec![0.0; ds.num_features], vec![1.0; ds.num_features])
    };
    let mut state = TrainState::init(&dims, cfg.seed);
    let mut rng = Rng::new(cfg.seed ^ 0x10a);
    let mut order: Vec<usize> = (0..ds.train_len()).collect();
    let mut log = TrainLog::default();
    let t0 = Instant::now();
    let mut x = vec![0.0f64; batch * ds.num_features];
    let mut y = vec![0.0f64; batch * classes];
    for _epoch in 0..cfg.epochs {
        rng.shuffle(&mut order);
        let mut epoch_sum = 0.0;
        let mut epoch_batches = 0usize;
        // Fixed-shape artifact: every step uses exactly `batch` rows. Small
        // training sets (or the remainder) wrap around the shuffled order.
        let steps_per_epoch = ds.train_len().div_ceil(batch);
        for step in 0..steps_per_epoch {
            for r in 0..batch {
                let s = order[(step * batch + r) % order.len()];
                let row = ds.train_row(s);
                for (j, &v) in row.iter().enumerate() {
                    x[r * ds.num_features + j] = (v - means[j]) / stds[j];
                }
                for c in 0..classes {
                    y[r * classes + c] = if c == ds.y_train[s] as usize { 1.0 } else { 0.0 };
                }
            }
            let loss = step_exe.step(&mut state, &x, &y, cfg.lr, cfg.momentum)?;
            log.steps += 1;
            epoch_sum += loss;
            epoch_batches += 1;
            if cfg.log_every > 0 && log.steps % cfg.log_every == 0 {
                log.losses.push((log.steps, loss));
            }
        }
        log.epoch_loss.push(epoch_sum / epoch_batches.max(1) as f64);
    }
    log.wall_seconds = t0.elapsed().as_secs_f64();
    // Fold the normalization into layer 0 (python layout: w[in][out]).
    let in_dim = dims[0];
    let out_dim = dims[1];
    for o in 0..out_dim {
        let mut shift = 0.0;
        for i in 0..in_dim {
            let w = &mut state.params[0][i * out_dim + o];
            *w /= stds[i];
            shift += *w * means[i];
        }
        state.params[1][o] -= shift;
    }
    Ok((state, log))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn log_renders() {
        let log = TrainLog { losses: vec![(1, 2.0)], epoch_loss: vec![2.0, 1.0], steps: 20, wall_seconds: 1.5 };
        let s = log.render();
        assert!(s.contains("2.0000") && s.contains("20 steps"));
    }
}
