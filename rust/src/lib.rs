//! # deep-positron
//!
//! A full-system reproduction of **"Performance-Efficiency Trade-off of
//! Low-Precision Numerical Formats in Deep Neural Networks"** (Carmichael et
//! al., CoNGA'19) — the Deep Positron accelerator study comparing **posit**,
//! **floating-point**, and **fixed-point** formats at [5, 8]-bit precision
//! with exact multiply-and-accumulate (EMAC) units.
//!
//! The stack has three layers (see DESIGN.md):
//!
//! * **L1/L2 (build time, Python)** — Pallas kernels + JAX graphs, AOT-lowered
//!   to HLO text in `artifacts/`.
//! * **L3 (this crate, Rust)** — bit-exact format codecs and EMACs
//!   ([`formats`]), the Deep Positron accelerator simulator ([`accel`]), an
//!   FPGA cost model ([`hw`]), dataset generators ([`datasets`]),
//!   quantization-error analysis ([`quant`]), a PJRT runtime that executes
//!   the AOT artifacts ([`runtime`]), the sharded multi-worker serving
//!   engine ([`serve`]), the bit-packed `.dpz` deployable model artifact
//!   ([`artifact`]), the mixed-precision auto-tuner ([`tune`]), the
//!   observability layer — lock-free latency histograms, flight-recorder
//!   request tracing, and a metrics snapshot exporter ([`obs`]) — and the
//!   experiment coordinator ([`coordinator`]).
//!
//! Quick taste (pure-Rust path, no artifacts needed):
//!
//! ```
//! use deep_positron::formats::{Format, FormatSpec, Quantizer, Emac};
//!
//! let spec = FormatSpec::parse("posit8es1").unwrap();
//! let fmt = spec.build();
//! let q = Quantizer::new(fmt.as_ref());
//! let (code, value) = q.quantize_f64(0.3);
//! assert!((value - 0.3).abs() < 0.01);
//! let mut emac = Emac::new(fmt.as_ref(), &q, 16);
//! let out = emac.dot(&[code; 4], &[code; 4], None, false);
//! assert!((q.decode(out).unwrap().to_f64() - 4.0 * value * value).abs() < 0.01);
//! ```
//!
//! For production-style serving — many (dataset, format) shards behind one
//! router, worker pools with deadline-aware dynamic batching, bounded
//! admission with load shedding, least-loaded routing, shared quantization
//! tables, per-shard latency percentiles — see [`serve`] and the `serve`
//! CLI mode (`cargo run --release -- serve`).

#![warn(missing_docs)]
// The exactness story (integer-only quire paths, DESIGN.md §14) leaves no
// room for `unsafe`: it is denied crate-wide and re-allowed only in the
// audited `util::pool` module. `repro lint` enforces the same allowlist
// token-level, so a new unsafe block trips two independent gates.
#![deny(unsafe_code)]

pub mod accel;
pub mod artifact;
pub mod coordinator;
pub mod datasets;
pub mod formats;
pub mod hw;
pub mod lint;
pub mod obs;
pub mod quant;
pub mod runtime;
pub mod serve;
pub mod tune;
pub mod util;
