//! `repro` — CLI for the Deep Positron reproduction.
//!
//! Every table and figure of the paper has a subcommand that regenerates it
//! (DESIGN.md §5 experiment index). Reports are printed and mirrored into
//! `results/`.

use std::collections::HashMap;
use std::process::ExitCode;

use anyhow::{anyhow, bail, Result};
use deep_positron::accel::DeepPositron;
use deep_positron::artifact::Artifact;
use deep_positron::coordinator::{experiments, report, trainer, Engine};
use deep_positron::datasets::{self, Scale};
use deep_positron::formats::FormatSpec;
use deep_positron::runtime::{artifacts_dir, Runtime};
use deep_positron::serve::{ServeEngine, ServeError, ShardConfig};
use deep_positron::{hw, lint, quant, tune};

const USAGE: &str = "\
repro — Deep Positron (CoNGA'19) reproduction driver

USAGE: repro <command> [--key value ...]

COMMANDS (one per paper artifact):
  synth-report   EMAC synthesis table (§5 prose)        [--k 784] [--bits 5,6,7,8]
  fig1           posit value distribution + param fit   [--seed 7]
  fig5           layer-wise quantization-error heatmaps [--dataset mnist] [--scale small|full]
  table1         8-bit inference accuracy, five tasks   [--engine sim|xla] [--scale small|full]
  fig6           degradation vs energy-delay-product    [--engine sim|xla] [--tasks a,b,c]
  fig7           degradation vs delay and power         (same flags as fig6)
  es-study       §5.1 posit es trade-off                (same flags)
  table2         posit-hardware comparison table
  conv           conv-net Table 1 on the raster tasks   [--tasks mnist,fashion] [--scale small|full]
                 (conv(5x5,s2)->pool(2)->dense, §11)
  tune           mixed-precision auto-tuner (§10, §13)  [--dataset iris] [--budget min-acc=0.95|max-edp=X|max-luts=N]
                                                        [--beam 2] [--eval-rows N] [--model mlp|conv]
                                                        [--prune 0.05|off] [--threads N]
                                                        (env TUNE_SMOKE_BUDGET_S=secs fails the run past a wall-clock budget)
  train          PJRT training loop (loss curve)        [--dataset mnist] [--epochs 10]
  pack           freeze a quantized model into a .dpz   [--dataset iris] [--out FILE] [--model mlp|conv]
                 deployable artifact (§16)              [--format posit8es1] [--plan FILE]
                 (--plan packs a tuned plan file: its per-layer assignment + provenance ride along)
  serve          sharded multi-worker inference engine  [--dataset iris] [--formats posit8es1,float8we4]
                                                        [--workers 2] [--requests 200] [--engine sim|xla]
                                                        [--max-queue 1024] [--deadline-ms N] [--model mlp|conv]
                                                        [--artifact FILE.dpz] [--obs-out FILE] [--json]
                 (--artifact cold-starts the shard from a packed .dpz — no training, no f64 pass, §16;
                  --obs-out writes BASE.obs.json + BASE.obs.prom + BASE.trace.jsonl, §15;
                  --json prints the machine-readable obs snapshot to stdout instead of the human report)
  lint           exactness-zone + artifact checker (§14) [--root DIR] [--corpus DIR] [--report FILE]
                 (non-zero exit on any finding; --corpus asserts every seeded fixture is caught)
  all            run every report at small scale

Common flags: --seed N (default 7), --scale small|full (default small).
";

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match run(&args) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

/// Flags that take no value (presence = true).
const BOOL_FLAGS: [&str; 1] = ["json"];

/// Parse `--key value` pairs (and bare boolean flags) after the subcommand.
fn parse_flags(args: &[String]) -> Result<HashMap<String, String>> {
    let mut flags = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        let k = args[i].strip_prefix("--").map(str::to_string);
        match (k, args.get(i + 1)) {
            (Some(k), _) if BOOL_FLAGS.contains(&k.as_str()) => {
                flags.insert(k, "true".to_string());
                i += 1;
            }
            (Some(k), Some(v)) => {
                flags.insert(k, v.clone());
                i += 2;
            }
            (Some(k), None) => bail!("flag --{k} missing a value"),
            (None, _) => bail!("unexpected argument {}", args[i]),
        }
    }
    Ok(flags)
}

struct Common {
    seed: u64,
    scale: Scale,
    engine: Engine,
    tasks: Vec<String>,
}

fn common(flags: &HashMap<String, String>) -> Result<Common> {
    let seed = flags.get("seed").map(|s| s.parse()).transpose()?.unwrap_or(7);
    let scale = match flags.get("scale").map(String::as_str) {
        None | Some("small") => Scale::Small,
        Some("full") => Scale::Full,
        Some(other) => bail!("unknown scale {other}"),
    };
    let engine = match flags.get("engine").map(String::as_str) {
        None | Some("sim") => Engine::Sim,
        Some("xla") => Engine::Xla,
        Some(other) => bail!("unknown engine {other}"),
    };
    let tasks = flags
        .get("tasks")
        .map(|t| t.split(',').map(str::to_string).collect())
        .unwrap_or_else(|| datasets::ALL.iter().map(|s| s.to_string()).collect());
    Ok(Common { seed, scale, engine, tasks })
}

fn maybe_runtime(engine: Engine) -> Result<Option<Runtime>> {
    Ok(match engine {
        Engine::Sim => None,
        Engine::Xla => Some(Runtime::new(&artifacts_dir())?),
    })
}

fn emit(name: &str, content: &str) -> Result<()> {
    println!("{content}");
    let path = report::write_report(name, content)?;
    eprintln!("[written to {}]", path.display());
    Ok(())
}

fn run(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first() else {
        print!("{USAGE}");
        return Ok(());
    };
    let flags = parse_flags(&args[1..])?;
    let c = common(&flags)?;
    match cmd.as_str() {
        "synth-report" => {
            let k: usize = flags.get("k").map(|s| s.parse()).transpose()?.unwrap_or(hw::DEFAULT_K);
            let bits: Vec<u32> = flags
                .get("bits")
                .map(|b| b.split(',').map(|x| x.parse().unwrap()).collect())
                .unwrap_or_else(|| vec![5, 6, 7, 8]);
            let reports = hw::sweep(&bits, k);
            emit("synth_report.md", &hw::render_table(&reports))?;
        }
        "fig1" => {
            let spec = FormatSpec::Posit { n: 8, es: 0 };
            let hist = quant::value_distribution(spec, 8.0, 32);
            let mut s = String::from("Fig 1a: posit8(es=0) value distribution over [-8, 8] (32 bins)\n\n");
            for (i, h) in hist.iter().enumerate() {
                let lo = -8.0 + 16.0 * i as f64 / 32.0;
                s.push_str(&format!("{lo:>6.2} | {}\n", "#".repeat(*h)));
            }
            // Fig 1b: trained ConvNet-like parameter distribution + error.
            let ds = datasets::load("iris", c.seed, c.scale);
            let mlp = experiments::train_model(&ds, c.seed);
            let params = &mlp.named_tensors().last().unwrap().data.clone();
            let (hist, err) = quant::param_error_profile(spec, params, 1.5, 24);
            s.push_str("\nFig 1b: trained-MLP parameter histogram | squared quantization error (posit8 es=0)\n\n");
            let max_h = *hist.iter().max().unwrap_or(&1) as f64;
            let max_e = err.iter().cloned().fold(1e-300, f64::max);
            for i in 0..hist.len() {
                let lo = -1.5 + 3.0 * i as f64 / 24.0;
                s.push_str(&format!(
                    "{lo:>6.2} | {:<24} | {}\n",
                    "#".repeat((hist[i] as f64 / max_h * 24.0) as usize),
                    "*".repeat((err[i] / max_e * 24.0) as usize)
                ));
            }
            emit("fig1.md", &s)?;
        }
        "fig5" => {
            let dataset = flags.get("dataset").map(String::as_str).unwrap_or("mnist").to_string();
            let cells = experiments::fig5(&dataset, c.scale, c.seed);
            let ns = [5, 6, 7, 8];
            let mut s = format!("Fig 5 — layer-wise quantization error, dataset = {dataset}\n\n");
            let fixed_title = "MSE_posit − MSE_fixed (negative ⇒ posit better)";
            let float_title = "MSE_posit − MSE_float (negative ⇒ posit better)";
            s.push_str(&quant::render_heatmap(&cells, &ns, quant::HeatCell::posit_minus_fixed, fixed_title));
            s.push('\n');
            s.push_str(&quant::render_heatmap(&cells, &ns, quant::HeatCell::posit_minus_float, float_title));
            emit(&format!("fig5_{dataset}.md"), &s)?;
        }
        "table1" => {
            let rt = maybe_runtime(c.engine)?;
            let rows = experiments::table1(c.engine, rt.as_ref(), c.scale, c.seed)?;
            emit("table1.md", &report::render_table1(&rows))?;
        }
        "fig6" | "fig7" => {
            let rt = maybe_runtime(c.engine)?;
            let tasks: Vec<&str> = c.tasks.iter().map(String::as_str).collect();
            let points = experiments::tradeoff_sweep(c.engine, rt.as_ref(), c.scale, c.seed, &tasks)?;
            if cmd == "fig6" {
                emit("fig6.md", &report::render_tradeoff(&points, "edp"))?;
            } else {
                let mut s = report::render_tradeoff(&points, "delay");
                s.push('\n');
                s.push_str(&report::render_tradeoff(&points, "power"));
                emit("fig7.md", &s)?;
            }
        }
        "es-study" => {
            let rt = maybe_runtime(c.engine)?;
            let tasks: Vec<&str> = c.tasks.iter().map(String::as_str).collect();
            let study = experiments::es_study(c.engine, rt.as_ref(), c.scale, c.seed, &tasks)?;
            emit("es_study.md", &report::render_es_study(&study))?;
        }
        "table2" => emit("table2.md", &report::render_table2())?,
        "conv" => {
            // The conv-capable layer IR end to end (DESIGN.md §11): train
            // the small conv net on the raster tasks and sweep the 8-bit
            // families through the conv EMAC datapath.
            let default_tasks = flags.get("tasks").is_none();
            let tasks: Vec<&str> = if default_tasks {
                vec!["mnist", "fashion"]
            } else {
                c.tasks.iter().map(String::as_str).collect()
            };
            if let Some(bad) = tasks.iter().find(|t| !matches!(**t, "mnist" | "fashion")) {
                bail!("conv consumes the 28x28 raster tasks (mnist | fashion), not {bad}");
            }
            let rows = experiments::conv_table(c.scale, c.seed, &tasks)?;
            let mut s = String::from(
                "conv-net Table 1 (conv4k5x5s2+pool2s2+flatten+dense10, conv EMAC datapath, §11)\n\n",
            );
            s.push_str(&report::render_table1(&rows));
            emit("conv_table1.md", &s)?;
        }
        "tune" => {
            let dataset = flags.get("dataset").map(String::as_str).unwrap_or("iris").to_string();
            let beam: usize = flags.get("beam").map(|s| s.parse()).transpose()?.unwrap_or(2);
            let conv = match flags.get("model").map(String::as_str) {
                None | Some("mlp") => false,
                Some("conv") => true,
                Some(other) => bail!("unknown model {other} (mlp | conv)"),
            };
            // Conv evaluations walk ~50k quire terms per sample: cap the
            // default validation rows so the descent stays interactive.
            let default_rows = if conv { 96 } else { usize::MAX };
            let eval_rows: usize = flags.get("eval-rows").map(|s| s.parse()).transpose()?.unwrap_or(default_rows);
            let ds = datasets::load(&dataset, c.seed, c.scale);
            if conv && ds.num_features != 28 * 28 {
                bail!("--model conv needs a 28x28 raster task (mnist | fashion), not {dataset}");
            }
            let mlp = experiments::model_for(&ds, c.seed, conv);
            let budget = match flags.get("budget") {
                Some(s) => tune::Budget::parse(s)
                    .ok_or_else(|| anyhow!("unparseable budget {s} (min-acc=0.95 | max-edp=X | max-luts=N)"))?,
                // Default: hold the best uniform 8-bit posit accuracy
                // within one point while minimizing network EDP.
                None => tune::default_budget(&ds, &mlp, eval_rows),
            };
            let mut cfg = tune::TuneConfig::new(budget).with_beam(beam).with_eval_rows(eval_rows);
            match flags.get("prune").map(String::as_str) {
                None => {}
                Some("off") => cfg = cfg.with_prune(None),
                Some(frac) => {
                    let drop: f64 = frac.parse().map_err(|_| anyhow!("unparseable --prune {frac} (fraction | off)"))?;
                    if !(0.0..=1.0).contains(&drop) {
                        bail!("--prune {frac} outside [0, 1]");
                    }
                    cfg = cfg.with_prune(Some(drop));
                }
            }
            if let Some(threads) = flags.get("threads") {
                cfg = cfg.with_threads(threads.parse()?);
            }
            // CI smoke budget: with TUNE_SMOKE_BUDGET_S set, the search
            // itself (not dataset load / training) must beat the clock —
            // the regression tripwire for the pruned+parallel pipeline.
            let started = std::time::Instant::now();
            let report_ = tune::tune(&ds, &mlp, &cfg);
            let tuned_in = started.elapsed();
            eprintln!("[search completed in {:.2}s]", tuned_in.as_secs_f64());
            let (memo_hits, memo_misses, evals_pruned) = tune::search::memo_counters();
            eprintln!("[tuner memo: {memo_hits} hit(s), {memo_misses} miss(es), {evals_pruned} pruned move(s)]");
            if let Some(budget_s) = std::env::var("TUNE_SMOKE_BUDGET_S").ok().and_then(|v| v.parse::<f64>().ok()) {
                let secs = tuned_in.as_secs_f64();
                if secs > budget_s {
                    bail!("tune search took {secs:.2}s, over the TUNE_SMOKE_BUDGET_S={budget_s}s budget");
                }
            }
            let name = if conv { format!("tune_conv_{dataset}.md") } else { format!("tune_{dataset}.md") };
            emit(&name, &report_.render())?;
        }
        "sweep" => {
            // Diagnostic: per-(task, config) accuracy at one bit-width.
            let n: u32 = flags.get("bits").map(|s| s.parse()).transpose()?.unwrap_or(8);
            let rt = maybe_runtime(c.engine)?;
            let mut s = format!("accuracy sweep at n={n} (engine {:?})\n\n| task | baseline |", c.engine);
            let specs = FormatSpec::sweep(n);
            for spec in &specs {
                s.push_str(&format!(" {} |", spec.name()));
            }
            s.push('\n');
            s.push_str(&format!("|---|---|{}", "---|".repeat(specs.len())));
            s.push('\n');
            for name in &c.tasks {
                let ds = datasets::load(name, c.seed, c.scale);
                let mlp = experiments::train_model(&ds, c.seed);
                s.push_str(&format!("| {name} | {:.1} |", mlp.accuracy(&ds) * 100.0));
                for &spec in &specs {
                    let acc = experiments::eval(c.engine, rt.as_ref(), &mlp, &ds, spec)?;
                    s.push_str(&format!(" {:.1} |", acc * 100.0));
                }
                s.push('\n');
            }
            emit(&format!("sweep_n{n}.md"), &s)?;
        }
        "train" => {
            let dataset = flags.get("dataset").map(String::as_str).unwrap_or("mnist").to_string();
            let epochs: usize = flags.get("epochs").map(|s| s.parse()).transpose()?.unwrap_or(10);
            let rt = Runtime::new(&artifacts_dir())?;
            let ds = datasets::load(&dataset, c.seed, c.scale);
            let cfg = trainer::LoopConfig { epochs, seed: c.seed, log_every: 10, ..Default::default() };
            let (state, log) = trainer::train_via_pjrt(&rt, &ds, &cfg)?;
            let mlp = state.to_mlp();
            let acc = mlp.accuracy(&ds);
            let mut s = format!("PJRT training loop — {dataset} ({} epochs)\n\n", epochs);
            s.push_str(&log.render());
            s.push_str(&format!("\nf32-trained test accuracy: {:.2}%\n", acc * 100.0));
            emit(&format!("train_{dataset}.md"), &s)?;
        }
        "pack" => {
            // Freeze a quantized model into the bit-packed `.dpz` deployable
            // artifact (DESIGN.md §16): train, compile, serialize the packed
            // code streams — `serve --artifact` boots from it with no
            // dataset, trainer, or f64 pass.
            let dataset = flags.get("dataset").map(String::as_str).unwrap_or("iris").to_string();
            let conv = match flags.get("model").map(String::as_str) {
                None | Some("mlp") => false,
                Some("conv") => true,
                Some(other) => bail!("unknown model {other} (mlp | conv)"),
            };
            let out = flags.get("out").cloned().unwrap_or_else(|| format!("{dataset}.dpz"));
            let ds = datasets::load(&dataset, c.seed, c.scale);
            if conv && ds.num_features != 28 * 28 {
                bail!("--model conv needs a 28x28 raster task (mnist | fashion), not {dataset}");
            }
            let mlp = experiments::model_for(&ds, c.seed, conv);
            let artifact = match flags.get("plan") {
                Some(path) => {
                    // A tuned plan carries its own per-layer assignment plus
                    // the provenance the artifact preserves (accuracy, prune
                    // line) — `--format` would contradict it.
                    if flags.contains_key("format") {
                        bail!("--plan carries its own per-layer formats (drop --format)");
                    }
                    let text = std::fs::read_to_string(path)?;
                    let plan = tune::TunePlan::parse(&text).ok_or_else(|| anyhow!("unparseable tune plan {path}"))?;
                    if plan.ir != mlp.ir() {
                        bail!(
                            "plan topology {} disagrees with the trained {dataset} model {}",
                            plan.ir.name(),
                            mlp.ir().name()
                        );
                    }
                    let dp = DeepPositron::compile_mixed(&mlp, plan.assignment.clone());
                    Artifact::from_network(&dataset, &dp).with_provenance(plan.accuracy, plan.pruned.clone())
                }
                None => {
                    let name = flags.get("format").map(String::as_str).unwrap_or("posit8es1");
                    let spec = FormatSpec::parse(name)
                        .filter(FormatSpec::is_supported)
                        .ok_or_else(|| anyhow!("unparseable or unsupported format {name}"))?;
                    Artifact::from_network(&dataset, &DeepPositron::compile(&mlp, spec))
                }
            };
            artifact.save(std::path::Path::new(&out))?;
            let bytes = std::fs::metadata(&out)?.len();
            println!(
                "packed {dataset} ({} / {}) into {out}: {bytes} bytes",
                artifact.ir().name(),
                artifact.mixed().name()
            );
        }
        "serve" => {
            let requests: usize = flags.get("requests").map(|s| s.parse()).transpose()?.unwrap_or(200);
            let workers: usize = flags.get("workers").map(|s| s.parse()).transpose()?.unwrap_or(2);
            let max_queue: usize = flags.get("max-queue").map(|s| s.parse()).transpose()?.unwrap_or(1024);
            let deadline = flags
                .get("deadline-ms")
                .map(|s| s.parse::<u64>())
                .transpose()?
                .map(std::time::Duration::from_millis);
            let (dataset, ds, shards) = match flags.get("artifact") {
                Some(path) => {
                    // Millisecond cold start (DESIGN.md §16): the packed
                    // artifact IS the execution plan — dataset, topology,
                    // and per-layer formats all ride inside it, so the
                    // flags that would pick them are contradictions.
                    for banned in ["dataset", "formats", "model"] {
                        if flags.contains_key(banned) {
                            bail!("--artifact carries its own dataset, topology, and formats (drop --{banned})");
                        }
                    }
                    let t0 = std::time::Instant::now();
                    let art = Artifact::load(std::path::Path::new(path)).map_err(|e| anyhow!("artifact {path}: {e}"))?;
                    eprintln!(
                        "[artifact {path}: {} / {} parsed in {:.2} ms]",
                        art.ir().name(),
                        art.mixed().name(),
                        t0.elapsed().as_secs_f64() * 1e3
                    );
                    let dataset = art.dataset().to_string();
                    // The dataset is loaded only to generate traffic and
                    // score replies — the shard itself boots from codes.
                    let ds = datasets::load(&dataset, c.seed, c.scale);
                    let shard = ShardConfig::from_artifact(std::sync::Arc::new(art))
                        .with_engine(c.engine)
                        .with_workers(workers)
                        .with_max_queue(max_queue);
                    (dataset, ds, vec![shard])
                }
                None => {
                    let dataset = flags.get("dataset").map(String::as_str).unwrap_or("iris").to_string();
                    let conv = match flags.get("model").map(String::as_str) {
                        None | Some("mlp") => false,
                        Some("conv") => true,
                        Some(other) => bail!("unknown model {other} (mlp | conv)"),
                    };
                    let formats: Vec<FormatSpec> = match flags.get("formats") {
                        Some(list) => list
                            .split(',')
                            .map(|name| FormatSpec::parse(name).ok_or_else(|| anyhow!("unparseable format {name}")))
                            .collect::<Result<Vec<_>>>()?,
                        None => vec![FormatSpec::Posit { n: 8, es: 1 }],
                    };
                    let ds = datasets::load(&dataset, c.seed, c.scale);
                    if conv && ds.num_features != 28 * 28 {
                        bail!("--model conv needs a 28x28 raster task (mnist | fashion), not {dataset}");
                    }
                    let mlp = experiments::model_for(&ds, c.seed, conv);
                    // One shard per requested format, all over the same trained
                    // model — the deployment-time format choice as a routing key.
                    // Conv models serve Sim-native (workers degrade Xla requests).
                    let shards: Vec<ShardConfig> = formats
                        .iter()
                        .map(|&spec| {
                            ShardConfig::new(&ds, mlp.clone(), spec)
                                .with_engine(c.engine)
                                .with_workers(workers)
                                .with_max_queue(max_queue)
                        })
                        .collect();
                    (dataset, ds, shards)
                }
            };
            let engine = ServeEngine::start(shards).map_err(|e| anyhow!("serve: {e}"))?;
            let keys = engine.shard_keys();
            // Observability outputs (DESIGN.md §15): BASE.obs.json (strict
            // snapshot), BASE.obs.prom (Prometheus text), BASE.trace.jsonl
            // (flight-recorder dump — also armed to fire automatically on
            // the first shed/expiry so an overload spike self-documents).
            let obs_base = flags.get("obs-out").map(|f| {
                let base = f.strip_suffix(".obs.json").or_else(|| f.strip_suffix(".json")).unwrap_or(f);
                base.to_string()
            });
            let trace_path = obs_base.as_ref().map(|b| std::path::PathBuf::from(format!("{b}.trace.jsonl")));
            if let Some(path) = &trace_path {
                engine.arm_trace_dump(path, 1);
            }
            // Open-loop submission: the engine self-protects, so overload
            // comes back as a typed shed instead of an ever-growing queue.
            let mut rxs = Vec::with_capacity(requests);
            let mut shed = 0usize;
            for i in 0..requests {
                let row = ds.test_row(i % ds.test_len()).to_vec();
                let sub = match deadline {
                    Some(budget) => engine.submit_with_deadline(&keys[i % keys.len()], row, budget),
                    None => engine.submit(&keys[i % keys.len()], row),
                };
                match sub {
                    Ok(rx) => rxs.push((i, rx)),
                    Err(ServeError::Overloaded { .. }) => shed += 1,
                    Err(e) => return Err(anyhow!("submit: {e}")),
                }
            }
            let mut correct = 0usize;
            let mut answered = 0usize;
            for (i, rx) in rxs {
                // A recv error is the deadline-expiry signal (the worker
                // dropped the reply channel instead of computing).
                if let Ok(reply) = rx.recv() {
                    answered += 1;
                    if reply.class == ds.y_test[i % ds.test_len()] as usize {
                        correct += 1;
                    }
                }
            }
            // Snapshot BEFORE shutdown (observe() reads the live shards),
            // after every reply has been collected so the histograms and
            // trace ring hold the whole run.
            let snapshot = engine.observe();
            if let Some(base) = &obs_base {
                std::fs::write(format!("{base}.obs.json"), snapshot.to_json())?;
                std::fs::write(format!("{base}.obs.prom"), snapshot.to_prometheus())?;
                if let Some(path) = &trace_path {
                    engine.recorder().dump_to(path)?;
                }
                eprintln!("[obs written to {base}.obs.json / {base}.obs.prom / {base}.trace.jsonl]");
            }
            let metrics = engine.shutdown();
            let mut s = format!(
                "sharded inference engine — {dataset}, {} shard(s) × {workers} worker(s), engine {:?}, \
                 max_queue {max_queue}\n\n",
                keys.len(),
                c.engine
            );
            s.push_str(&metrics.render());
            s.push_str(&format!(
                "\nsubmitted {requests}: answered {answered}, shed {shed}, expired {}\n",
                metrics.total_expired()
            ));
            if answered > 0 {
                s.push_str(&format!("served accuracy: {:.1}%\n", correct as f64 / answered as f64 * 100.0));
            }
            if flags.contains_key("json") {
                // Machine-readable mode: stdout carries EXACTLY the strict
                // obs snapshot JSON (the open-loop report used to interleave
                // human text on stdout); the human report still lands in
                // results/ for the archive.
                let path = report::write_report(&format!("serve_{dataset}.md"), &s)?;
                eprintln!("[written to {}]", path.display());
                println!("{}", snapshot.to_json());
            } else {
                emit(&format!("serve_{dataset}.md"), &s)?;
            }
        }
        "lint" => {
            // Static analysis (DESIGN.md §14): the exactness-zone scan plus
            // the artifact auditor. Findings go to stdout (and --report),
            // and any finding fails the process — this is the CI gate.
            let root = match flags.get("root") {
                Some(dir) => std::path::PathBuf::from(dir),
                None => std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")),
            };
            let mut out = String::new();
            let failure = match flags.get("corpus") {
                Some(dir) => {
                    let rep = lint::check_corpus(&root, std::path::Path::new(dir))
                        .map_err(|e| anyhow!("lint corpus: {e}"))?;
                    for line in &rep.lines {
                        out.push_str(line);
                        out.push('\n');
                    }
                    let summary = if rep.missed.is_empty() {
                        format!("lint corpus: all {} fixture(s) caught", rep.lines.len())
                    } else {
                        format!("lint corpus: {} of {} fixture(s) NOT caught", rep.missed.len(), rep.lines.len())
                    };
                    out.push_str(&summary);
                    out.push('\n');
                    (!rep.missed.is_empty()).then_some(summary)
                }
                None => {
                    let findings = lint::lint_tree(&root).map_err(|e| anyhow!("lint: {e}"))?;
                    for f in &findings {
                        out.push_str(&f.to_string());
                        out.push('\n');
                    }
                    let summary = if findings.is_empty() {
                        "lint: clean (0 findings)".to_string()
                    } else {
                        format!("lint: {} finding(s)", findings.len())
                    };
                    out.push_str(&summary);
                    out.push('\n');
                    (!findings.is_empty()).then_some(summary)
                }
            };
            print!("{out}");
            if let Some(path) = flags.get("report") {
                std::fs::write(path, &out)?;
                eprintln!("[findings written to {path}]");
            }
            if let Some(summary) = failure {
                bail!("{summary}");
            }
        }
        "all" => {
            for sub in ["synth-report", "fig1", "table2", "es-study", "table1", "fig6", "fig7", "tune", "conv"] {
                println!("==== {sub} ====");
                run(&[sub.to_string(), "--seed".into(), c.seed.to_string()])?;
            }
            for ds in ["mnist", "fashion"] {
                run(&["fig5".into(), "--dataset".into(), ds.into(), "--seed".into(), c.seed.to_string()])?;
            }
        }
        "help" | "--help" | "-h" => print!("{USAGE}"),
        other => bail!("unknown command {other}\n\n{USAGE}"),
    }
    Ok(())
}
