//! Network-level hardware costing for per-layer format assignments, over
//! the typed layer IR.
//!
//! The paper's Figs. 6–7 cost ONE EMAC at a fixed dot-product length; a
//! deployment plan needs the cost of the whole network. Deep Positron's
//! dataflow is a bank of EMACs per layer with the layers running serially;
//! the IR ([`NetIr`]) says how each layer instantiates its bank, so per
//! layer `i` with format `F_i` (see [`crate::accel::LayerGeom`]):
//!
//! * resources (LUTs/FFs/DSPs) = `banks_i ×` the per-EMAC synthesis of
//!   `F_i`, with the Eq. (2) accumulator sized for the layer's OWN
//!   accumulation length `k_i` (dense: fan-in + 1 bias; conv:
//!   `kh·kw·in_ch + 1` — a 26-product conv EMAC no longer pays for a
//!   784-product quire; pool: the `k²` window) — exactly the bound
//!   `DeepPositron::compile*` asserts the quire against. Dense banks hold
//!   one EMAC per output neuron; conv banks one per output channel; pool
//!   banks one accumulate-only unit per channel (costed as a full EMAC — a
//!   deliberate, documented over-estimate that keeps the model monotone);
//!   flatten is wiring and costs nothing.
//! * energy of one inference = `fan_in_i × num_outputs_i ×` per-MAC energy
//!   (every unit of the bank streams its receptive field per output);
//! * latency of one inference = `fan_in_i × outputs_per_bank_i ×` critical
//!   path (each unit produces its outputs serially, the bank in lock-step)
//!   + the pipeline fill latency;
//! * network EDP = total energy × total latency — the tuner's default
//!   budget/objective axis, the network analogue of Fig. 6's x-axis.
//!
//! Dense-only networks reduce exactly to the pre-IR formulas (banks =
//! fan-out, outputs-per-bank = 1), so [`network_cost`] — the dense
//! `dims`-based entry — is unchanged observable behavior. Every term is
//! monotone in format width, so any single-layer downgrade strictly
//! reduces the modeled EDP — the property the Pareto search leans on
//! (guarded by `tests/prop_hw.rs`).

use std::collections::HashMap;

use crate::accel::{LayerKind, NetIr};
use crate::formats::{FormatSpec, MixedSpec};
use crate::hw;
use crate::hw::SynthReport;

/// Modeled whole-network deployment cost of one per-layer assignment.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NetworkCost {
    /// Look-up tables across every layer's EMAC bank.
    pub luts: f64,
    /// Flip-flops across every bank.
    pub ffs: f64,
    /// DSP slices across every bank.
    pub dsps: f64,
    /// Switched energy of one full inference pass (every MAC of every
    /// layer), pJ.
    pub energy_pj: f64,
    /// Latency of one inference (layers serial, banks internally parallel),
    /// ns.
    pub delay_ns: f64,
    /// Energy-delay product of one inference, pJ·ns.
    pub edp_pj_ns: f64,
    /// Widest Eq. (2) quire any layer provisions, bits.
    pub max_quire_bits: u32,
}

/// Cost a per-layer assignment against a network's typed IR — the general
/// entry point ([`network_cost`] is the dense-`dims` special case).
pub fn network_cost_ir(mixed: &MixedSpec, ir: &NetIr) -> NetworkCost {
    assert_eq!(mixed.len(), ir.len(), "IR and assignment must carry one format per layer");
    let mut c = NetworkCost {
        luts: 0.0,
        ffs: 0.0,
        dsps: 0.0,
        energy_pj: 0.0,
        delay_ns: 0.0,
        edp_pj_ns: 0.0,
        max_quire_bits: 0,
    };
    for (geom, &spec) in ir.geoms().iter().zip(mixed.layers()) {
        if matches!(geom.kind, LayerKind::Flatten) {
            continue; // pure wiring: no EMACs, no cycles
        }
        let fan_in = geom.fan_in();
        let banks = geom.banks();
        let outputs = geom.out_shape.len();
        // k per Eq. (2): the layer's own accumulation length (fan-in + bias
        // for weighted layers), matching the compile-time
        // `assert_quire_fits(layer.eq2_k())` bound.
        let r = hw::synthesize(spec, geom.eq2_k());
        c.luts += r.luts * banks as f64;
        c.ffs += r.ffs * banks as f64;
        c.dsps += r.dsps * banks as f64;
        c.energy_pj += r.energy_pj * (fan_in * outputs) as f64;
        c.delay_ns += r.critical_path_ns * (fan_in * geom.outputs_per_bank()) as f64 + r.latency_ns;
        c.max_quire_bits = c.max_quire_bits.max(r.quire_bits);
    }
    c.edp_pj_ns = c.energy_pj * c.delay_ns;
    c
}

/// Cost a per-layer assignment for a dense network with layer widths
/// `dims` (`[in, h1, ..., out]`; one assignment entry per adjacent pair) —
/// the classic dense-only view, bit-identical to the pre-IR cost model.
pub fn network_cost(mixed: &MixedSpec, dims: &[usize]) -> NetworkCost {
    assert_eq!(mixed.len() + 1, dims.len(), "dims must be [in, h1, ..., out] with one format per layer");
    network_cost_ir(mixed, &NetIr::dense(dims))
}

/// Pre-synthesized per-`(layer, format)` EMAC cost table.
///
/// [`network_cost_ir`] re-runs [`hw::synthesize`] for every layer of every
/// assignment it costs; the tuner costs thousands of assignments over the
/// same IR and a small candidate alphabet, so the distinct `(eq2_k, format)`
/// synthesis calls number only `layers × formats`. `CostTable::new` runs
/// them once up front; [`CostTable::network`] then walks the exact same
/// per-layer summation loop as `network_cost_ir` over the cached reports —
/// same floating-point operations in the same order, so the result is
/// bit-identical (asserted by `cached_table_matches_direct_costing`).
/// Formats outside the precomputed alphabet fall back to a direct
/// synthesis call, never a panic.
#[derive(Debug, Clone)]
pub struct CostTable {
    ir: NetIr,
    per_layer: Vec<HashMap<FormatSpec, SynthReport>>,
}

impl CostTable {
    /// Synthesize every `(layer, format)` pair of the alphabet up front
    /// (flatten layers cost nothing and cache nothing). Duplicate specs in
    /// the alphabet are synthesized once.
    pub fn new(ir: &NetIr, specs: &[FormatSpec]) -> CostTable {
        let per_layer = ir
            .geoms()
            .iter()
            .map(|geom| {
                let mut m = HashMap::new();
                if !matches!(geom.kind, LayerKind::Flatten) {
                    for &spec in specs {
                        m.entry(spec).or_insert_with(|| hw::synthesize(spec, geom.eq2_k()));
                    }
                }
                m
            })
            .collect();
        CostTable { ir: ir.clone(), per_layer }
    }

    /// The IR this table was built over.
    pub fn ir(&self) -> &NetIr {
        &self.ir
    }

    /// [`network_cost_ir`] against this table's IR, bit-identical, with
    /// every per-EMAC synthesis served from the cache.
    pub fn network(&self, mixed: &MixedSpec) -> NetworkCost {
        assert_eq!(mixed.len(), self.ir.len(), "IR and assignment must carry one format per layer");
        let mut c = NetworkCost {
            luts: 0.0,
            ffs: 0.0,
            dsps: 0.0,
            energy_pj: 0.0,
            delay_ns: 0.0,
            edp_pj_ns: 0.0,
            max_quire_bits: 0,
        };
        for ((geom, &spec), cache) in self.ir.geoms().iter().zip(mixed.layers()).zip(&self.per_layer) {
            if matches!(geom.kind, LayerKind::Flatten) {
                continue; // pure wiring: no EMACs, no cycles
            }
            let fan_in = geom.fan_in();
            let banks = geom.banks();
            let outputs = geom.out_shape.len();
            let fresh;
            let r = match cache.get(&spec) {
                Some(r) => r,
                None => {
                    fresh = hw::synthesize(spec, geom.eq2_k());
                    &fresh
                }
            };
            c.luts += r.luts * banks as f64;
            c.ffs += r.ffs * banks as f64;
            c.dsps += r.dsps * banks as f64;
            c.energy_pj += r.energy_pj * (fan_in * outputs) as f64;
            c.delay_ns += r.critical_path_ns * (fan_in * geom.outputs_per_bank()) as f64 + r.latency_ns;
            c.max_quire_bits = c.max_quire_bits.max(r.quire_bits);
        }
        c.edp_pj_ns = c.energy_pj * c.delay_ns;
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::FormatSpec;

    const DIMS: [usize; 4] = [30, 16, 8, 2];

    fn uniform(name: &str) -> MixedSpec {
        MixedSpec::uniform(FormatSpec::parse(name).unwrap(), DIMS.len() - 1)
    }

    fn conv_ir() -> NetIr {
        NetIr::parse("1x28x28:conv4k5x5s2+pool2s2+flatten+dense10").unwrap()
    }

    #[test]
    fn narrower_uniform_assignment_costs_strictly_less() {
        let wide = network_cost(&uniform("posit8es1"), &DIMS);
        let narrow = network_cost(&uniform("posit6es1"), &DIMS);
        assert!(narrow.luts < wide.luts);
        assert!(narrow.energy_pj < wide.energy_pj);
        assert!(narrow.delay_ns < wide.delay_ns);
        assert!(narrow.edp_pj_ns < wide.edp_pj_ns);
        assert!(narrow.max_quire_bits < wide.max_quire_bits);
    }

    #[test]
    fn single_layer_downgrade_strictly_reduces_edp() {
        // The descent invariant: every per-layer downgrade move the search
        // considers lowers the modeled network EDP.
        let base = uniform("posit8es1");
        let base_cost = network_cost(&base, &DIMS);
        for li in 0..base.len() {
            for down in ["posit7es1", "posit8es0", "float8we4", "fixed8q5", "fixed5q3"] {
                let m = base.with_layer(li, FormatSpec::parse(down).unwrap());
                let c = network_cost(&m, &DIMS);
                assert!(c.edp_pj_ns < base_cost.edp_pj_ns, "layer {li} -> {down} did not reduce EDP");
            }
        }
    }

    #[test]
    fn layer_k_follows_fan_in() {
        // A big-fan-in first layer must provision a wider quire than the
        // same format on the 8-wide penultimate layer (k = fan-in + 1, the
        // bias-inclusive bound the compiled plan asserts against).
        let m = uniform("posit8es1");
        let r_in = hw::synthesize(m.layers()[0], DIMS[0] + 1);
        let r_mid = hw::synthesize(m.layers()[2], DIMS[2] + 1);
        assert!(r_in.quire_bits > r_mid.quire_bits);
        // And the network-wide max reports the widest of them.
        assert_eq!(network_cost(&m, &DIMS).max_quire_bits, r_in.quire_bits);
    }

    #[test]
    fn dense_ir_costing_matches_the_dims_path_exactly() {
        let m = uniform("posit7es1");
        let via_dims = network_cost(&m, &DIMS);
        let via_ir = network_cost_ir(&m, &NetIr::dense(&DIMS));
        assert_eq!(via_dims, via_ir);
    }

    #[test]
    fn conv_quire_is_sized_by_the_receptive_field_not_the_input_width() {
        let ir = conv_ir();
        let spec = FormatSpec::parse("posit8es1").unwrap();
        let m = MixedSpec::uniform(spec, ir.len());
        let c = network_cost_ir(&m, &ir);
        // Widest layer k is the dense head (144 + 1), not the 784-wide
        // input (which a dense net on the same pixels would provision).
        assert_eq!(c.max_quire_bits, hw::synthesize(spec, 145).quire_bits);
        let dense_equiv = network_cost(&MixedSpec::uniform(spec, 2), &[784, 100, 10]);
        assert!(
            c.max_quire_bits < dense_equiv.max_quire_bits,
            "conv quire {} not below dense-on-pixels quire {}",
            c.max_quire_bits,
            dense_equiv.max_quire_bits
        );
        // Conv bank: 4 EMACs (one per output channel) — far fewer units
        // than the dense head's 10, but each sweeps 144 output pixels, so
        // the conv layer dominates latency, not resources.
        let conv_only = network_cost_ir(
            &MixedSpec::uniform(spec, 1),
            &NetIr::parse("1x28x28:conv4k5x5s2").unwrap(),
        );
        let r = hw::synthesize(spec, 26);
        assert_eq!(conv_only.luts, r.luts * 4.0);
        assert_eq!(conv_only.delay_ns, r.critical_path_ns * (25 * 144) as f64 + r.latency_ns);
        assert_eq!(conv_only.energy_pj, r.energy_pj * (25 * 576) as f64);
    }

    #[test]
    fn conv_downgrades_stay_monotone() {
        let ir = conv_ir();
        let spec = FormatSpec::parse("posit8es1").unwrap();
        let base = MixedSpec::uniform(spec, ir.len());
        let base_cost = network_cost_ir(&base, &ir);
        for li in [0usize, 1, 3] {
            // (layer 2 is the flatten: format changes there cost nothing)
            let m = base.with_layer(li, FormatSpec::parse("posit6es1").unwrap());
            let c = network_cost_ir(&m, &ir);
            assert!(c.edp_pj_ns < base_cost.edp_pj_ns, "downgrading layer {li} did not reduce EDP");
        }
        let m = base.with_layer(2, FormatSpec::parse("posit6es1").unwrap());
        assert_eq!(network_cost_ir(&m, &ir).luts, base_cost.luts, "flatten must cost nothing");
    }

    #[test]
    #[should_panic(expected = "one format per layer")]
    fn dims_and_assignment_must_agree() {
        let _ = network_cost(&uniform("posit8es1"), &[4, 3]);
    }

    #[test]
    fn cached_table_matches_direct_costing() {
        // The precomputed table must be bit-identical to network_cost_ir on
        // every assignment — in-alphabet lookups and out-of-alphabet
        // fallbacks alike, on dense and conv topologies.
        let alphabet: Vec<FormatSpec> =
            ["posit8es1", "posit6es1", "float8we4", "fixed7q3"].iter().map(|s| FormatSpec::parse(s).unwrap()).collect();
        for ir in [NetIr::dense(&DIMS), conv_ir()] {
            let table = CostTable::new(&ir, &alphabet);
            let mut rng = crate::util::Rng::new(11);
            for _ in 0..64 {
                let layers: Vec<FormatSpec> = (0..ir.len())
                    .map(|_| {
                        if rng.chance(0.25) {
                            FormatSpec::parse("fixed5q2").unwrap() // outside the alphabet
                        } else {
                            alphabet[rng.below(alphabet.len())]
                        }
                    })
                    .collect();
                let m = MixedSpec::new(layers);
                assert_eq!(table.network(&m), network_cost_ir(&m, &ir), "{}", m.name());
            }
        }
    }
}
