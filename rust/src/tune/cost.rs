//! Network-level hardware costing for per-layer format assignments.
//!
//! The paper's Figs. 6–7 cost ONE EMAC at a fixed dot-product length; a
//! deployment plan needs the cost of the whole network. Deep Positron's
//! dataflow is a bank of EMACs per layer (one per output neuron) with the
//! layers running serially, so per layer `i` with formats `F_i`:
//!
//! * resources (LUTs/FFs/DSPs) = `fan_out_i ×` the per-EMAC synthesis of
//!   `F_i`, with the Eq. (2) accumulator sized for `fan_in_i + 1` terms —
//!   the layer's dot product plus its bias, exactly the bound
//!   `DeepPositron::compile*` asserts the quire against — per the
//!   per-task/per-layer `k` rule (a 4-feature layer no longer pays for a
//!   784-product quire);
//! * energy of one inference = `fan_in_i × fan_out_i ×` per-MAC energy
//!   (every EMAC in the bank streams the layer's fan-in);
//! * latency of one inference = `fan_in_i ×` critical path (the bank runs
//!   its fan-in in lock-step cycles) + the pipeline fill latency;
//! * network EDP = total energy × total latency — the tuner's default
//!   budget/objective axis, the network analogue of Fig. 6's x-axis.
//!
//! Every term is monotone in format width, so any single-layer downgrade
//! strictly reduces the modeled EDP — the property the Pareto search leans
//! on (guarded by `tests/prop_hw.rs`).

use crate::formats::MixedSpec;
use crate::hw;

/// Modeled whole-network deployment cost of one per-layer assignment.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NetworkCost {
    /// Look-up tables across every layer's EMAC bank.
    pub luts: f64,
    /// Flip-flops across every bank.
    pub ffs: f64,
    /// DSP slices across every bank.
    pub dsps: f64,
    /// Switched energy of one full inference pass (every MAC of every
    /// layer), pJ.
    pub energy_pj: f64,
    /// Latency of one inference (layers serial, banks internally parallel),
    /// ns.
    pub delay_ns: f64,
    /// Energy-delay product of one inference, pJ·ns.
    pub edp_pj_ns: f64,
    /// Widest Eq. (2) quire any layer provisions, bits.
    pub max_quire_bits: u32,
}

/// Cost a per-layer assignment for a network with layer widths `dims`
/// (`[in, h1, ..., out]`; one assignment entry per adjacent pair).
pub fn network_cost(mixed: &MixedSpec, dims: &[usize]) -> NetworkCost {
    assert_eq!(mixed.len() + 1, dims.len(), "dims must be [in, h1, ..., out] with one format per layer");
    let mut c = NetworkCost {
        luts: 0.0,
        ffs: 0.0,
        dsps: 0.0,
        energy_pj: 0.0,
        delay_ns: 0.0,
        edp_pj_ns: 0.0,
        max_quire_bits: 0,
    };
    for (li, &spec) in mixed.layers().iter().enumerate() {
        let (fan_in, fan_out) = (dims[li], dims[li + 1]);
        // k = fan-in + 1: the bias is one more quire addend, matching the
        // compile-time `assert_quire_fits(dims[li] + 1)` bound.
        let r = hw::synthesize(spec, fan_in + 1);
        let macs = (fan_in * fan_out) as f64;
        c.luts += r.luts * fan_out as f64;
        c.ffs += r.ffs * fan_out as f64;
        c.dsps += r.dsps * fan_out as f64;
        c.energy_pj += r.energy_pj * macs;
        c.delay_ns += r.critical_path_ns * fan_in as f64 + r.latency_ns;
        c.max_quire_bits = c.max_quire_bits.max(r.quire_bits);
    }
    c.edp_pj_ns = c.energy_pj * c.delay_ns;
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::FormatSpec;

    const DIMS: [usize; 4] = [30, 16, 8, 2];

    fn uniform(name: &str) -> MixedSpec {
        MixedSpec::uniform(FormatSpec::parse(name).unwrap(), DIMS.len() - 1)
    }

    #[test]
    fn narrower_uniform_assignment_costs_strictly_less() {
        let wide = network_cost(&uniform("posit8es1"), &DIMS);
        let narrow = network_cost(&uniform("posit6es1"), &DIMS);
        assert!(narrow.luts < wide.luts);
        assert!(narrow.energy_pj < wide.energy_pj);
        assert!(narrow.delay_ns < wide.delay_ns);
        assert!(narrow.edp_pj_ns < wide.edp_pj_ns);
        assert!(narrow.max_quire_bits < wide.max_quire_bits);
    }

    #[test]
    fn single_layer_downgrade_strictly_reduces_edp() {
        // The descent invariant: every per-layer downgrade move the search
        // considers lowers the modeled network EDP.
        let base = uniform("posit8es1");
        let base_cost = network_cost(&base, &DIMS);
        for li in 0..base.len() {
            for down in ["posit7es1", "posit8es0", "float8we4", "fixed8q5", "fixed5q3"] {
                let m = base.with_layer(li, FormatSpec::parse(down).unwrap());
                let c = network_cost(&m, &DIMS);
                assert!(c.edp_pj_ns < base_cost.edp_pj_ns, "layer {li} -> {down} did not reduce EDP");
            }
        }
    }

    #[test]
    fn layer_k_follows_fan_in() {
        // A big-fan-in first layer must provision a wider quire than the
        // same format on the 8-wide penultimate layer (k = fan-in + 1, the
        // bias-inclusive bound the compiled plan asserts against).
        let m = uniform("posit8es1");
        let r_in = hw::synthesize(m.layers()[0], DIMS[0] + 1);
        let r_mid = hw::synthesize(m.layers()[2], DIMS[2] + 1);
        assert!(r_in.quire_bits > r_mid.quire_bits);
        // And the network-wide max reports the widest of them.
        assert_eq!(network_cost(&m, &DIMS).max_quire_bits, r_in.quire_bits);
    }

    #[test]
    #[should_panic(expected = "one format per layer")]
    fn dims_and_assignment_must_agree() {
        let _ = network_cost(&uniform("posit8es1"), &[4, 3]);
    }
}
