//! Mixed-precision auto-tuning: per-layer format search over the
//! accuracy × hardware Pareto frontier (DESIGN.md §10).
//!
//! The paper samples the performance-efficiency trade-off one uniform
//! format at a time; Cheetah (Langroudi et al., 2019) shows the same EMAC
//! substrate wins hardest when precision is assigned **per layer**. This
//! subsystem turns the repository's two existing measurement axes into an
//! automatic deployment planner:
//!
//! * **Accuracy axis** — every candidate assignment compiles through the
//!   heterogeneous execution plans ([`DeepPositron::compile_mixed`]) and
//!   evaluates on the task's held-out split via the batched evaluator.
//! * **Hardware axis** — [`network_cost_ir`] sums per-layer
//!   [`hw::synthesize`] reports over the network's typed IR
//!   (`crate::accel::NetIr`), each layer's EMAC bank sized by Eq. (2) for
//!   *that layer's* receptive-field fan-in (a conv layer provisions its
//!   `kh·kw·in_ch`-term quire, not an input-width one), into network
//!   LUT/energy/delay/EDP totals. [`network_cost`] is the dense-`dims`
//!   special case.
//!
//! [`tune`] enumerates uniform candidates from `FormatSpec::sweep(5..=8)`,
//! runs the per-layer sensitivity pre-pass ([`sensitivity::prepass`]) to
//! build a 1%/5%-drop bitwidth table and prune each layer's candidate
//! pool, runs a deterministic greedy/beam per-layer descent under a user
//! budget ([`Budget`]) with each round's candidates fanned out across the
//! shared worker pool, extracts the non-dominated frontier
//! ([`pareto_frontier`]) from everything it evaluated, and emits a
//! serializable [`TunePlan`] (carrying the pruning provenance) that
//! serving shards can start from directly ([`TunePlan::shard_config`]).
//! Output is bit-identical at any pool width and with pruning on or off
//! whenever the pruned pools contain the unpruned optimum (DESIGN.md §13).
//!
//! Entry points: the `tune` CLI subcommand, `examples/autotune.rs`, and
//! `benches/tune_search.rs` (pruned/parallel vs serial/unpruned search
//! throughput).
//!
//! [`DeepPositron::compile_mixed`]: crate::accel::DeepPositron::compile_mixed
//! [`hw::synthesize`]: crate::hw::synthesize

pub mod cost;
pub mod pareto;
pub mod search;
pub mod sensitivity;

pub use cost::{network_cost, network_cost_ir, CostTable, NetworkCost};
pub use pareto::{pareto_frontier, ParetoPoint};
pub use search::{default_budget, tune, Budget, TuneConfig, TunePlan, TuneReport};
pub use sensitivity::{prepass, LayerSensitivity, SensitivityTable};
