//! Per-layer sensitivity pre-pass: the cheap screening stage that prunes
//! the tuner's candidate pools before descent (DESIGN.md §13).
//!
//! The mupod bitwidth-table methodology: perturb ONE layer at a time away
//! from a trusted baseline assignment, measure the accuracy drop on a small
//! screening prefix of the held-out split, and record — per layer — the
//! minimum bit-width whose best candidate stays within a drop threshold.
//! Layers that tolerate narrow formats (typically mid-network feature
//! layers) get their whole narrow sweep; layers that collapse below some
//! width (typically the input and classifier layers) have everything
//! narrower pruned away before the expensive descent ever scores it. The
//! screening evaluations are `layers × widths × family-configs` cheap
//! passes, an order of magnitude fewer than what descent would spend
//! discovering the same floors the hard way.
//!
//! Determinism: every screening evaluation is a pure function of
//! `(mlp, assignment, screening rows)` — batched EMAC accuracy is
//! bit-identical at any pool width — and the table is assembled in fixed
//! (layer, width) order, so the pre-pass returns the same
//! [`SensitivityTable`] whether the perturbations were evaluated serially
//! or fanned out across the worker pool
//! (`prepass_is_identical_at_any_pool_width`).

use std::ops::RangeInclusive;

use crate::accel::{Datapath, DeepPositron, Mlp};
use crate::datasets::Dataset;
use crate::formats::{FormatSpec, MixedSpec};
use crate::util::pool::WorkerPool;

/// Cap on screening rows: enough signal to rank single-layer perturbations
/// (collapse-vs-tolerate is a coarse distinction), few enough that the
/// whole pre-pass costs less than a handful of full descent evaluations.
pub const SCREEN_ROWS: usize = 48;

/// What one layer's perturbation screening measured.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerSensitivity {
    /// Layer index (0-based, input first).
    pub layer: usize,
    /// Human label, e.g. `conv1` / `dense4`.
    pub label: String,
    /// Best (smallest) accuracy drop at each screened width, ascending
    /// width order; widths past an early stop are not recorded.
    pub best_drop: Vec<(u32, f64)>,
    /// Minimum screened width whose best candidate drops ≤ 1 point.
    pub bits_1pct: Option<u32>,
    /// Minimum screened width whose best candidate drops ≤ 5 points.
    pub bits_5pct: Option<u32>,
    /// The pruning floor: minimum width whose best candidate stays within
    /// the configured drop threshold (the widest screened width when none
    /// does — pruning must never empty a pool).
    pub floor: u32,
}

/// The per-layer bitwidth table the pre-pass emits: screening metadata plus
/// one [`LayerSensitivity`] per layer, in layer order.
#[derive(Debug, Clone, PartialEq)]
pub struct SensitivityTable {
    /// The assignment the perturbations departed from (the descent start).
    pub baseline: MixedSpec,
    /// Baseline accuracy on the screening rows.
    pub baseline_accuracy: f64,
    /// Held-out rows each screening evaluation used.
    pub screen_rows: usize,
    /// Accuracy-drop budget (fraction, e.g. `0.05`) a width must meet to
    /// become a layer's floor.
    pub drop_threshold: f64,
    /// Screening evaluations spent (baseline + every perturbation).
    pub evals: usize,
    /// One entry per layer, input first.
    pub layers: Vec<LayerSensitivity>,
}

impl SensitivityTable {
    /// Prune each layer's candidate pool to the formats at or above the
    /// layer's floor. A pool that would come out empty (the floor sits
    /// above every candidate's width) falls back to the full pool —
    /// pruning narrows the search, it never strands it.
    pub fn pools(&self, candidates: &[FormatSpec]) -> Vec<Vec<FormatSpec>> {
        self.layers
            .iter()
            .map(|l| {
                let kept: Vec<FormatSpec> = candidates.iter().copied().filter(|c| c.n() >= l.floor).collect();
                if kept.is_empty() {
                    candidates.to_vec()
                } else {
                    kept
                }
            })
            .collect()
    }

    /// One-line provenance for tuned plans (`pruned=` in the plan codec):
    /// the drop budget, the per-layer floors, and the screening fidelity.
    pub fn provenance(&self) -> String {
        let floors: Vec<String> = self.layers.iter().map(|l| l.floor.to_string()).collect();
        format!(
            "sensitivity drop<={:.1}% floors={} screen_rows={}",
            self.drop_threshold * 100.0,
            floors.join(","),
            self.screen_rows,
        )
    }

    /// Markdown rendering of the bitwidth table (the report section the
    /// `repro tune` CLI emits).
    pub fn render(&self) -> String {
        let mut s = format!(
            "## Per-layer sensitivity (baseline {}, {:.2}% on {} screening rows, {} evals)\n\n",
            self.baseline.name(),
            self.baseline_accuracy * 100.0,
            self.screen_rows,
            self.evals,
        );
        s.push_str("| layer | min bits (≤1% drop) | min bits (≤5% drop) | pruned floor | best drop per width |\n");
        s.push_str("|---|---|---|---|---|\n");
        let col = |b: Option<u32>| b.map_or_else(|| "-".to_string(), |n| n.to_string());
        for l in &self.layers {
            let drops: Vec<String> = l.best_drop.iter().map(|(n, d)| format!("{n}b:{:.1}%", d * 100.0)).collect();
            s.push_str(&format!(
                "| {}{} | {} | {} | {} | {} |\n",
                l.label,
                l.layer + 1,
                col(l.bits_1pct),
                col(l.bits_5pct),
                l.floor,
                drops.join(" "),
            ));
        }
        s
    }
}

/// Run the sensitivity pre-pass: screen every single-layer perturbation of
/// `baseline` over the widths in `bits` (ascending), fanning the
/// perturbations of each `(layer, width)` group across `pool`, and build
/// the per-layer bitwidth table. `eval_rows` caps the screening rows
/// (further capped at [`SCREEN_ROWS`]); `drop_threshold` is the accuracy
/// budget a width must meet to become a layer's pruning floor.
///
/// A layer's screening stops early once a width's best drop reaches
/// `min(1%, drop_threshold)` — wider formats strictly extend narrower
/// ones' value sets here, so the thresholds above are already resolved.
pub fn prepass(
    ds: &Dataset,
    mlp: &Mlp,
    baseline: &MixedSpec,
    bits: RangeInclusive<u32>,
    drop_threshold: f64,
    eval_rows: usize,
    pool: &WorkerPool,
) -> SensitivityTable {
    let screen_rows = eval_rows.min(SCREEN_ROWS).min(ds.test_len()).max(1);
    let inline = WorkerPool::new(1);
    let base_dp = DeepPositron::compile_mixed(mlp, baseline.clone());
    let baseline_accuracy = base_dp.accuracy_on_with(ds, Datapath::Emac, screen_rows, pool);
    let ir = mlp.ir();
    let mut evals = 1usize;
    let mut layers = Vec::with_capacity(mlp.layers.len());
    for li in 0..mlp.layers.len() {
        let base_spec = baseline.layers()[li];
        let mut best_drop = Vec::new();
        let mut bits_1pct = None;
        let mut bits_5pct = None;
        let mut floor = None;
        for n in bits.clone() {
            let todo: Vec<MixedSpec> = FormatSpec::sweep(n)
                .into_iter()
                .filter(|&c| c != base_spec)
                .map(|c| baseline.with_layer(li, c))
                .collect();
            // Candidate-level fan-out; each evaluation's batches run inline
            // (width-1 pool) so fan-outs never nest. A serial caller's
            // single-candidate groups keep batch-level parallelism instead.
            let batch_pool = if pool.threads() > 1 && todo.len() > 1 { &inline } else { pool };
            let jobs: Vec<_> = todo
                .iter()
                .map(|mixed| {
                    let mixed = mixed.clone();
                    move || {
                        let dp = base_dp.recompile_mixed(mlp, mixed);
                        dp.accuracy_on_with(ds, Datapath::Emac, screen_rows, batch_pool)
                    }
                })
                .collect();
            evals += jobs.len();
            let mut best = pool.run_map(jobs).into_iter().fold(f64::NEG_INFINITY, f64::max);
            if base_spec.n() == n {
                // The baseline spec is itself a width-n candidate: drop 0
                // by definition, no evaluation spent.
                best = best.max(baseline_accuracy);
            }
            let drop = (baseline_accuracy - best).max(0.0);
            best_drop.push((n, drop));
            if bits_1pct.is_none() && drop <= 0.01 {
                bits_1pct = Some(n);
            }
            if bits_5pct.is_none() && drop <= 0.05 {
                bits_5pct = Some(n);
            }
            if floor.is_none() && drop <= drop_threshold {
                floor = Some(n);
            }
            if drop <= drop_threshold.min(0.01) {
                break; // every threshold resolved; wider widths only repeat it
            }
        }
        layers.push(LayerSensitivity {
            layer: li,
            label: ir.geoms()[li].kind_label().to_string(),
            best_drop,
            bits_1pct,
            bits_5pct,
            // No screened width met the budget: floor at the widest width
            // screened, so pruning keeps only the most capable candidates.
            floor: floor.unwrap_or(*bits.end()),
        });
    }
    SensitivityTable {
        baseline: baseline.clone(),
        baseline_accuracy,
        screen_rows,
        drop_threshold,
        evals,
        layers,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::mlp::{train, TrainConfig};
    use crate::datasets::{self, Scale};
    use crate::util::Rng;

    fn trained_iris() -> (Mlp, Dataset) {
        let ds = datasets::load("iris", 5, Scale::Small);
        let (norm, means, stds) = ds.normalized();
        let mut rng = Rng::new(2);
        let mut mlp = Mlp::new(&[4, 10, 8, 3], &mut rng);
        train(&mut mlp, &norm, &TrainConfig { epochs: 60, ..Default::default() });
        crate::accel::mlp::fold_input_normalization(&mut mlp, &means, &stds);
        (mlp, ds)
    }

    #[test]
    fn prepass_is_identical_at_any_pool_width() {
        let (mlp, ds) = trained_iris();
        let baseline = MixedSpec::uniform(FormatSpec::Posit { n: 8, es: 1 }, 3);
        let serial = prepass(&ds, &mlp, &baseline, 5..=8, 0.05, usize::MAX, &WorkerPool::new(1));
        let fanned = prepass(&ds, &mlp, &baseline, 5..=8, 0.05, usize::MAX, &WorkerPool::new(4));
        assert_eq!(serial, fanned);
    }

    #[test]
    fn floors_land_in_range_and_prune_monotonically() {
        let (mlp, ds) = trained_iris();
        let baseline = MixedSpec::uniform(FormatSpec::Posit { n: 8, es: 1 }, 3);
        let table = prepass(&ds, &mlp, &baseline, 5..=8, 0.05, usize::MAX, &WorkerPool::new(2));
        assert_eq!(table.layers.len(), 3);
        let candidates: Vec<FormatSpec> = (5..=8).flat_map(FormatSpec::sweep).collect();
        let pools = table.pools(&candidates);
        for (l, pool) in table.layers.iter().zip(&pools) {
            assert!((5..=8).contains(&l.floor), "floor {} out of range", l.floor);
            assert!(!pool.is_empty(), "pruning emptied layer {}", l.layer);
            assert!(pool.iter().all(|c| candidates.contains(c)));
            assert!(pool.iter().all(|c| c.n() >= l.floor));
            // Thresholds nest: a width good to 1% is good to 5%.
            if let (Some(a), Some(b)) = (l.bits_1pct, l.bits_5pct) {
                assert!(b <= a, "5% floor {b} above 1% floor {a}");
            }
        }
        // The baseline's own width always meets the drop budget (drop 0),
        // so no floor exceeds it and the descent start stays reachable.
        for (l, pool) in table.layers.iter().zip(&pools) {
            assert!(l.floor <= 8);
            assert!(pool.contains(&baseline.layers()[l.layer]));
        }
    }

    #[test]
    fn provenance_is_one_line_and_render_has_one_row_per_layer() {
        let (mlp, ds) = trained_iris();
        let baseline = MixedSpec::uniform(FormatSpec::Posit { n: 8, es: 1 }, 3);
        let table = prepass(&ds, &mlp, &baseline, 6..=8, 0.05, 32, &WorkerPool::new(2));
        let prov = table.provenance();
        assert!(!prov.contains('\n'), "{prov}");
        assert!(prov.starts_with("sensitivity drop<=5.0% floors="), "{prov}");
        assert!(prov.ends_with(&format!("screen_rows={}", table.screen_rows)), "{prov}");
        let rendered = table.render();
        assert_eq!(rendered.matches("\n| dense").count(), 3, "{rendered}");
        assert!(rendered.contains("Per-layer sensitivity"));
    }
}
