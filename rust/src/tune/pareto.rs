//! Non-dominated frontier extraction over the accuracy × hardware plane.
//!
//! A point dominates another when it is at least as accurate AND at most
//! as expensive (network EDP), strictly better on at least one axis. The
//! tuner logs every assignment it evaluates and reports the non-dominated
//! subset — the reproduction's searched analogue of the paper's sampled
//! Fig. 6 trade-off curve.

use crate::formats::MixedSpec;
use crate::tune::cost::NetworkCost;

/// One scored assignment: validation accuracy (higher is better) and
/// modeled network cost (lower EDP is better).
#[derive(Debug, Clone)]
pub struct ParetoPoint {
    /// The per-layer format assignment.
    pub mixed: MixedSpec,
    /// Validation accuracy of the compiled mixed plan.
    pub accuracy: f64,
    /// Modeled whole-network hardware cost.
    pub cost: NetworkCost,
}

impl ParetoPoint {
    /// Whether `self` dominates `other`: no worse on both axes
    /// (accuracy ↑, EDP ↓) and strictly better on at least one.
    pub fn dominates(&self, other: &ParetoPoint) -> bool {
        let no_worse = self.accuracy >= other.accuracy && self.cost.edp_pj_ns <= other.cost.edp_pj_ns;
        no_worse && (self.accuracy > other.accuracy || self.cost.edp_pj_ns < other.cost.edp_pj_ns)
    }
}

/// Extract the non-dominated subset of `points`, sorted by ascending EDP.
///
/// Deterministic: ties sort by descending accuracy, then assignment name;
/// coincident (accuracy, EDP) pairs keep the name-first representative.
/// The result contains no point dominated by any *input* point.
pub fn pareto_frontier(points: &[ParetoPoint]) -> Vec<ParetoPoint> {
    let mut sorted: Vec<&ParetoPoint> = points.iter().collect();
    sorted.sort_by(|a, b| {
        a.cost
            .edp_pj_ns
            .partial_cmp(&b.cost.edp_pj_ns)
            .expect("EDP is never NaN")
            .then(b.accuracy.partial_cmp(&a.accuracy).expect("accuracy is never NaN"))
            .then_with(|| a.mixed.name().cmp(&b.mixed.name()))
    });
    // One ascending-EDP sweep: a point joins the frontier iff it improves
    // on the best accuracy seen so far (anything else is dominated by an
    // earlier, cheaper-or-equal point).
    let mut out: Vec<ParetoPoint> = Vec::new();
    let mut best_acc = f64::NEG_INFINITY;
    for p in sorted {
        if p.accuracy > best_acc {
            out.push(p.clone());
            best_acc = p.accuracy;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::FormatSpec;
    use crate::tune::cost::network_cost;

    fn point(name: &str, accuracy: f64, edp: f64) -> ParetoPoint {
        let mixed = MixedSpec::parse(name).unwrap();
        let mut cost = network_cost(&mixed, &[4, 3]);
        cost.edp_pj_ns = edp; // synthetic axis value for the dominance tests
        ParetoPoint { mixed, accuracy, cost }
    }

    #[test]
    fn dominance_requires_strictness_on_one_axis() {
        let a = point("posit8es1", 0.9, 10.0);
        let b = point("posit7es1", 0.9, 10.0);
        assert!(!a.dominates(&b), "coincident points do not dominate each other");
        assert!(!b.dominates(&a));
        assert!(point("posit8es1", 0.9, 9.0).dominates(&b));
        assert!(point("posit8es1", 0.95, 10.0).dominates(&b));
        assert!(!point("posit8es1", 0.95, 11.0).dominates(&b), "trade-off points are incomparable");
    }

    #[test]
    fn frontier_drops_every_dominated_point() {
        let pts = vec![
            point("posit5es0", 0.60, 1.0),
            point("posit6es0", 0.80, 2.0),
            point("fixed6q3", 0.70, 2.5),  // dominated by posit6es0
            point("posit8es1", 0.95, 8.0),
            point("float8we4", 0.94, 9.0), // dominated by posit8es1
        ];
        let f = pareto_frontier(&pts);
        let names: Vec<String> = f.iter().map(|p| p.mixed.name()).collect();
        assert_eq!(names, vec!["posit5es0", "posit6es0", "posit8es1"]);
        for a in &f {
            for b in &pts {
                assert!(!b.dominates(a), "{} dominates frontier point {}", b.mixed.name(), a.mixed.name());
            }
        }
        // Sorted by ascending EDP with strictly increasing accuracy.
        for w in f.windows(2) {
            assert!(w[0].cost.edp_pj_ns < w[1].cost.edp_pj_ns);
            assert!(w[0].accuracy < w[1].accuracy);
        }
    }

    #[test]
    fn frontier_is_deterministic_under_permutation() {
        let a = vec![point("posit5es0", 0.6, 1.0), point("posit6es0", 0.8, 2.0), point("posit8es1", 0.9, 3.0)];
        let mut b = a.clone();
        b.reverse();
        let fa: Vec<String> = pareto_frontier(&a).iter().map(|p| p.mixed.name()).collect();
        let fb: Vec<String> = pareto_frontier(&b).iter().map(|p| p.mixed.name()).collect();
        assert_eq!(fa, fb);
    }

    #[test]
    fn coincident_points_keep_one_representative() {
        let pts = vec![point("posit8es1", 0.9, 5.0), point("float8we4", 0.9, 5.0)];
        let f = pareto_frontier(&pts);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].mixed.name(), "float8we4", "name-order tie-break is deterministic");
    }

    /// The retired O(n²) frontier: keep every point no input point
    /// dominates, then apply the sweep's exact ordering and coincident
    /// dedup rules. The reference the sort-based sweep is checked against.
    fn quadratic_frontier(points: &[ParetoPoint]) -> Vec<ParetoPoint> {
        let mut out: Vec<ParetoPoint> =
            points.iter().filter(|p| points.iter().all(|q| !q.dominates(p))).cloned().collect();
        out.sort_by(|a, b| {
            a.cost
                .edp_pj_ns
                .partial_cmp(&b.cost.edp_pj_ns)
                .expect("EDP is never NaN")
                .then(b.accuracy.partial_cmp(&a.accuracy).expect("accuracy is never NaN"))
                .then_with(|| a.mixed.name().cmp(&b.mixed.name()))
        });
        out.dedup_by(|a, b| a.accuracy == b.accuracy && a.cost.edp_pj_ns == b.cost.edp_pj_ns);
        out
    }

    #[test]
    fn sweep_matches_quadratic_scan_on_random_cost_clouds() {
        // The O(n log n) sweep must agree with the O(n²) dominance scan on
        // arbitrary clouds — including duplicated axis values and fully
        // coincident points, which small discrete grids force constantly.
        let specs = FormatSpec::sweep(8);
        crate::util::prop::forall("pareto sweep == quadratic scan", |rng| {
            let n = 1 + rng.below(40);
            let pts: Vec<ParetoPoint> = (0..n)
                .map(|_| {
                    let spec = specs[rng.below(specs.len())];
                    let mixed = MixedSpec::uniform(spec, 1 + rng.below(3));
                    let mut cost = network_cost(&MixedSpec::uniform(spec, 2), &[4, 3, 2]);
                    cost.edp_pj_ns = (1 + rng.below(8)) as f64;
                    ParetoPoint { mixed, accuracy: rng.below(6) as f64 / 5.0, cost }
                })
                .collect();
            let fast: Vec<(String, f64, f64)> =
                pareto_frontier(&pts).iter().map(|p| (p.mixed.name(), p.accuracy, p.cost.edp_pj_ns)).collect();
            let slow: Vec<(String, f64, f64)> =
                quadratic_frontier(&pts).iter().map(|p| (p.mixed.name(), p.accuracy, p.cost.edp_pj_ns)).collect();
            assert_eq!(fast, slow);
        });
    }

    #[test]
    fn real_sweep_frontier_contains_no_dominated_point() {
        // Cost real uniform assignments over a WDBC-shaped net; accuracy is
        // a synthetic monotone-ish stand-in so the test stays hardware-only.
        let dims = [30usize, 16, 8, 2];
        let mut pts = Vec::new();
        for n in 5..=8u32 {
            for spec in FormatSpec::sweep(n) {
                let mixed = MixedSpec::uniform(spec, dims.len() - 1);
                let cost = network_cost(&mixed, &dims);
                let accuracy = n as f64 / 10.0 + if spec.family() == "posit" { 0.02 } else { 0.0 };
                pts.push(ParetoPoint { mixed, accuracy, cost });
            }
        }
        let f = pareto_frontier(&pts);
        assert!(!f.is_empty());
        for a in &f {
            for b in &pts {
                assert!(!b.dominates(a));
            }
        }
    }
}
