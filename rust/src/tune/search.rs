//! Deterministic greedy/beam per-layer descent under a hardware or
//! accuracy budget, and the serializable [`TunePlan`] it emits.
//!
//! The search space is `candidates^layers` (candidates =
//! `FormatSpec::sweep(5..=8)`, ~43 configs) — far too large to enumerate,
//! but single-layer moves compose well because each layer's EMAC bank is
//! independent in the cost model and quantization error is approximately
//! layer-local. The descent therefore: (1) scores every *uniform*
//! candidate, (2) seeds a beam with the best feasible start, (2.5) runs
//! the per-layer sensitivity pre-pass ([`crate::tune::sensitivity`]) from
//! that start and prunes each layer's candidate pool to the formats above
//! its drop floor, (3) per round, expands every beam state by every
//! surviving single-layer reassignment, keeps the top `beam` feasible
//! states, and stops when the round fails to improve the incumbent.
//!
//! The pipeline is fast AND deterministic (DESIGN.md §13): a descent
//! round's candidates fan out across the shared [`WorkerPool`] through the
//! thread-safe memoized [`Evaluator`] (each candidate recompiles only the
//! ≤ 2 layers its move touched, via `DeepPositron::recompile_mixed`, and
//! runs its batches inline so fan-outs never nest), results merge in
//! generation order, every ranking tie-breaks on the assignment name, and
//! no randomness enters anywhere — the same inputs produce the same
//! [`TunePlan`] at ANY pool width, serial included.

use std::collections::{HashMap, HashSet};
use std::ops::RangeInclusive;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::accel::{Datapath, DeepPositron, LayerKind, Mlp, NetIr};
use crate::datasets::Dataset;
use crate::formats::{FormatSpec, MixedSpec};
use crate::quant;
use crate::serve::ShardConfig;
use crate::tune::cost::{network_cost_ir, CostTable, NetworkCost};
use crate::tune::pareto::{pareto_frontier, ParetoPoint};
use crate::tune::sensitivity::{self, SensitivityTable};
use crate::util::pool::WorkerPool;

// Process-wide tuner observability counters (DESIGN.md §15): relaxed,
// monotone, read only by `ObsSnapshot::collect` — never by the search, so
// they cannot perturb its determinism.
static MEMO_HITS: AtomicU64 = AtomicU64::new(0);
static MEMO_MISSES: AtomicU64 = AtomicU64::new(0);
static EVALS_PRUNED: AtomicU64 = AtomicU64::new(0);

/// Cumulative tuner memoization traffic since process start, for the obs
/// snapshot: `(memo_hits, memo_misses, evals_pruned)` — evaluator cache
/// hits, distinct assignments actually compiled + scored, and per-layer
/// `(layer, format)` moves the sensitivity pre-pass removed from the
/// descent's candidate pools.
pub fn memo_counters() -> (u64, u64, u64) {
    (
        MEMO_HITS.load(Ordering::Relaxed),
        MEMO_MISSES.load(Ordering::Relaxed),
        EVALS_PRUNED.load(Ordering::Relaxed),
    )
}

/// The user-supplied constraint the descent optimizes under.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Budget {
    /// Maximize accuracy subject to network EDP ≤ this many pJ·ns
    /// (CLI: `--budget max-edp=1.5e6`).
    MaxEdp(f64),
    /// Maximize accuracy subject to total network LUTs ≤ this
    /// (CLI: `--budget max-luts=40000`).
    MaxLuts(f64),
    /// Minimize network EDP subject to accuracy ≥ this
    /// (CLI: `--budget min-acc=0.95`).
    MinAcc(f64),
}

impl Budget {
    /// Parse a CLI budget: `max-edp=X`, `max-luts=X`, or `min-acc=X`.
    pub fn parse(s: &str) -> Option<Budget> {
        let (kind, value) = s.split_once('=')?;
        let v: f64 = value.parse().ok()?;
        match kind {
            "max-edp" => Some(Budget::MaxEdp(v)),
            "max-luts" => Some(Budget::MaxLuts(v)),
            "min-acc" => Some(Budget::MinAcc(v)),
            _ => None,
        }
    }

    /// Whether a scored assignment satisfies the budget.
    pub fn feasible(&self, accuracy: f64, cost: &NetworkCost) -> bool {
        match *self {
            Budget::MaxEdp(e) => cost.edp_pj_ns <= e,
            Budget::MaxLuts(l) => cost.luts <= l,
            Budget::MinAcc(a) => accuracy >= a,
        }
    }

    /// Minimization key for ranking feasible assignments: cost-budgets
    /// maximize accuracy (tie: cheaper EDP), the accuracy budget minimizes
    /// EDP (tie: higher accuracy). Lower key = better.
    fn key(&self, accuracy: f64, cost: &NetworkCost) -> (f64, f64) {
        match self {
            Budget::MaxEdp(_) | Budget::MaxLuts(_) => (-accuracy, cost.edp_pj_ns),
            Budget::MinAcc(_) => (cost.edp_pj_ns, -accuracy),
        }
    }

    /// Human label for reports.
    pub fn label(&self) -> String {
        match *self {
            Budget::MaxEdp(e) => format!("max-edp={e:.4}"),
            Budget::MaxLuts(l) => format!("max-luts={l:.1}"),
            Budget::MinAcc(a) => format!("min-acc={a:.4}"),
        }
    }
}

/// Tuner knobs. Construct with [`TuneConfig::new`] and chain the `with_*`
/// setters.
#[derive(Debug, Clone)]
pub struct TuneConfig {
    /// The constraint to optimize under.
    pub budget: Budget,
    /// Per-layer candidate bit-widths (the paper's sweep range).
    pub bits: RangeInclusive<u32>,
    /// Beam width; 1 is pure greedy descent.
    pub beam: usize,
    /// Safety cap on descent rounds.
    pub max_rounds: usize,
    /// Cap on validation rows per evaluation (the full held-out split by
    /// default; tests shrink it).
    pub eval_rows: usize,
    /// Sensitivity pre-pass drop budget: prune each layer's candidate pool
    /// to widths whose best perturbation stays within this accuracy drop
    /// (fraction). `None` disables pruning (the exhaustive search).
    pub prune_drop: Option<f64>,
    /// Worker-pool width for candidate fan-out: `None` shares the
    /// process-wide [`WorkerPool::global`]; `Some(n)` pins a private
    /// width-`n` pool (`Some(1)` forces the serial search — bit-identical
    /// output either way).
    pub threads: Option<usize>,
}

impl TuneConfig {
    /// Defaults: bits 5..=8, beam 2, 16 rounds, full validation split,
    /// 5%-drop sensitivity pruning, shared global pool.
    pub fn new(budget: Budget) -> TuneConfig {
        TuneConfig {
            budget,
            bits: 5..=8,
            beam: 2,
            max_rounds: 16,
            eval_rows: usize::MAX,
            prune_drop: Some(0.05),
            threads: None,
        }
    }

    /// Set the beam width (min 1; 1 = greedy).
    pub fn with_beam(mut self, beam: usize) -> TuneConfig {
        self.beam = beam.max(1);
        self
    }

    /// Set the candidate bit-width range.
    pub fn with_bits(mut self, bits: RangeInclusive<u32>) -> TuneConfig {
        self.bits = bits;
        self
    }

    /// Cap the validation rows per evaluation.
    pub fn with_eval_rows(mut self, rows: usize) -> TuneConfig {
        self.eval_rows = rows.max(1);
        self
    }

    /// Set (or, with `None`, disable) the sensitivity-pruning drop budget.
    pub fn with_prune(mut self, drop: Option<f64>) -> TuneConfig {
        self.prune_drop = drop;
        self
    }

    /// Pin candidate fan-out to a private pool of the given width instead
    /// of the shared global pool (min 1; 1 = fully serial).
    pub fn with_threads(mut self, threads: usize) -> TuneConfig {
        self.threads = Some(threads.max(1));
        self
    }
}

/// The tuned deployment plan: a per-layer assignment plus the scores it
/// was selected on. Serializable ([`TunePlan::to_text`] /
/// [`TunePlan::parse`]) and directly servable
/// ([`TunePlan::shard_config`]).
#[derive(Debug, Clone)]
pub struct TunePlan {
    /// Task the plan was tuned for.
    pub dataset: String,
    /// Network layer widths, `[in, h1, ..., out]` (the flat view of `ir`).
    pub dims: Vec<usize>,
    /// The network's typed layer IR — what the hardware cost recomputes
    /// from, and what makes conv plans serializable (DESIGN.md §11).
    pub ir: NetIr,
    /// The selected per-layer format assignment.
    pub assignment: MixedSpec,
    /// Validation accuracy of the compiled mixed plan.
    pub accuracy: f64,
    /// Modeled whole-network hardware cost.
    pub cost: NetworkCost,
    /// Whether the plan satisfies the budget it was tuned under (false
    /// means the budget was unattainable and this is the closest point).
    pub feasible: bool,
    /// Pruning provenance: the sensitivity pre-pass summary
    /// ([`SensitivityTable::provenance`]) the search pruned under, `None`
    /// for an unpruned (exhaustive) search. Rides through the text codec —
    /// a deployed serving shard can always say what was pruned away from
    /// the plan it runs.
    pub pruned: Option<String>,
}

impl TunePlan {
    /// Serialize to a line-oriented `key=value` text block. Hardware cost
    /// is *not* stored — [`TunePlan::parse`] recomputes it from the
    /// assignment and the layer IR, so the cost model stays the single
    /// source of truth. The `ir=` line carries the typed topology
    /// ([`NetIr::name`]); the optional `pruned=` line carries the
    /// sensitivity provenance; plans written before either existed omit
    /// them and parse as dense / unpruned.
    pub fn to_text(&self) -> String {
        let mut s = format!(
            "dataset={}\ndims={}\nir={}\nlayers={}\naccuracy={:.6}\nfeasible={}\n",
            self.dataset,
            self.dims.iter().map(usize::to_string).collect::<Vec<_>>().join(","),
            self.ir.name(),
            self.assignment.name(),
            self.accuracy,
            self.feasible,
        );
        if let Some(p) = &self.pruned {
            s.push_str(&format!("pruned={p}\n"));
        }
        s
    }

    /// Parse the [`TunePlan::to_text`] form; recomputes [`NetworkCost`]
    /// from the assignment and IR. Returns `None` on any malformed field,
    /// or when the `ir=` topology disagrees with `dims=`.
    ///
    /// Plan text is *untrusted* (hand-edited deployment files): every field
    /// is range-checked before it reaches code that asserts — widths are
    /// capped ([`crate::accel::ir::MAX_PARSED_DIM`]) and non-zero, formats
    /// must be buildable ([`FormatSpec::is_supported`]), accuracy must be a
    /// fraction — so garbage always comes back as `None`, never a panic.
    pub fn parse(s: &str) -> Option<TunePlan> {
        let mut fields: HashMap<&str, &str> = HashMap::new();
        for line in s.lines() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line.split_once('=')?;
            fields.insert(k, v);
        }
        let dataset = (*fields.get("dataset")?).to_string();
        let dims = fields
            .get("dims")?
            .split(',')
            .map(|d| d.parse().ok())
            .collect::<Option<Vec<usize>>>()?;
        if dims.len() < 2 || dims.iter().any(|&d| d == 0 || d > crate::accel::ir::MAX_PARSED_DIM) {
            return None;
        }
        let ir = match fields.get("ir") {
            Some(text) => NetIr::parse(text)?,
            // Pre-IR plans carried only the flat widths: dense topology.
            None => NetIr::try_dense(&dims).ok()?,
        };
        if ir.dims() != dims {
            return None;
        }
        let assignment = MixedSpec::parse(fields.get("layers")?)?;
        if assignment.len() != ir.len() {
            return None;
        }
        // A parseable name is not a buildable format: the cost model below
        // instantiates each spec, whose constructors assert width bounds.
        if !assignment.layers().iter().all(|spec| spec.is_supported()) {
            return None;
        }
        let accuracy: f64 = fields.get("accuracy")?.parse().ok()?;
        if !(0.0..=1.0).contains(&accuracy) {
            return None;
        }
        let feasible: bool = fields.get("feasible")?.parse().ok()?;
        let pruned = fields.get("pruned").map(|p| (*p).to_string());
        let cost = network_cost_ir(&assignment, &ir);
        Some(TunePlan { dataset, dims, ir, assignment, accuracy, cost, feasible, pruned })
    }

    /// A serving-shard config that deploys this plan: the shard's workers
    /// compile the mixed execution plan instead of a uniform spec, and the
    /// shard's routing key carries the assignment's joined name.
    pub fn shard_config(&self, ds: &Dataset, mlp: Mlp) -> ShardConfig {
        ShardConfig::new(ds, mlp, self.assignment.layers()[0]).with_mixed(self.assignment.clone())
    }
}

/// Everything one [`tune`] run produced.
#[derive(Debug, Clone)]
pub struct TuneReport {
    /// The selected plan.
    pub plan: TunePlan,
    /// Non-dominated subset of every assignment the search evaluated,
    /// ascending EDP.
    pub frontier: Vec<ParetoPoint>,
    /// The comparison anchor: the best-accuracy uniform 8-bit posit.
    pub reference: ParetoPoint,
    /// Budget the search ran under.
    pub budget: Budget,
    /// Distinct assignments evaluated (compile + validation passes).
    pub evaluated: usize,
    /// Descent rounds executed before convergence.
    pub rounds: usize,
    /// Weight-tensor quantization MSE (paper Eq. 3) of each layer under
    /// its assigned format — the "why" column of the per-layer report.
    pub layer_mse: Vec<f64>,
    /// The sensitivity pre-pass table the search pruned under (`None` when
    /// pruning was disabled).
    pub sensitivity: Option<SensitivityTable>,
}

impl TuneReport {
    /// Render the markdown report the `tune` CLI emits.
    pub fn render(&self) -> String {
        let mut s = format!(
            "# Mixed-precision tune — {} (budget {}, {} assignments evaluated, {} rounds)\n\n",
            self.plan.dataset,
            self.budget.label(),
            self.evaluated,
            self.rounds,
        );
        let line = |label: &str, p: &ParetoPoint| {
            format!(
                "| {label} | {} | {:.2} | {:.3e} | {:.0} | {:.1} | {:.1} |\n",
                p.mixed.name(),
                p.accuracy * 100.0,
                p.cost.edp_pj_ns,
                p.cost.luts,
                p.cost.energy_pj,
                p.cost.delay_ns,
            )
        };
        s.push_str("| | assignment | acc % | EDP (pJ·ns) | LUTs | energy (pJ) | delay (ns) |\n");
        s.push_str("|---|---|---|---|---|---|---|\n");
        s.push_str(&line("uniform posit8 (ref)", &self.reference));
        let plan_pt =
            ParetoPoint { mixed: self.plan.assignment.clone(), accuracy: self.plan.accuracy, cost: self.plan.cost };
        s.push_str(&line(if self.plan.feasible { "tuned plan" } else { "tuned plan (budget unattainable)" }, &plan_pt));
        s.push_str(&format!(
            "\ntuned vs reference: {:+.2} acc pts at {:.2}× the EDP, {:.2}× the LUTs\n",
            (self.plan.accuracy - self.reference.accuracy) * 100.0,
            self.plan.cost.edp_pj_ns / self.reference.cost.edp_pj_ns,
            self.plan.cost.luts / self.reference.cost.luts,
        ));
        s.push_str(&format!("\n## Pareto frontier ({} points)\n\n", self.frontier.len()));
        s.push_str("| # | assignment | acc % | EDP (pJ·ns) | LUTs | quire bits |\n|---|---|---|---|---|---|\n");
        for (i, p) in self.frontier.iter().enumerate() {
            s.push_str(&format!(
                "| {i} | {} | {:.2} | {:.3e} | {:.0} | {} |\n",
                p.mixed.name(),
                p.accuracy * 100.0,
                p.cost.edp_pj_ns,
                p.cost.luts,
                p.cost.max_quire_bits,
            ));
        }
        s.push_str("\n## Per-layer assignment\n\n");
        s.push_str("| layer | fan-in | fan-out | format | weight MSE (Eq. 3) | quire bits |\n");
        s.push_str("|---|---|---|---|---|---|\n");
        for (li, (&spec, &mse)) in self.plan.assignment.layers().iter().zip(&self.layer_mse).enumerate() {
            // k = the layer's own Eq. (2) accumulation length (fan-in + 1
            // bias for weighted layers), the same sizing `network_cost_ir`
            // and the compile-time quire check use. Flatten is pure wiring
            // and provisions no quire.
            let geom = &self.plan.ir.geoms()[li];
            let quire = match geom.kind {
                LayerKind::Flatten => 0,
                _ => crate::hw::synthesize(spec, geom.eq2_k()).quire_bits,
            };
            s.push_str(&format!(
                "| {}{} | {} | {} | {} | {:.3e} | {} |\n",
                geom.kind_label(),
                li + 1,
                geom.fan_in(),
                self.plan.dims[li + 1],
                spec.name(),
                mse,
                quire,
            ));
        }
        if let Some(table) = &self.sensitivity {
            s.push('\n');
            s.push_str(&table.render());
        }
        s.push_str("\n## Plan\n\n```\n");
        s.push_str(&self.plan.to_text());
        s.push_str("```\n");
        s
    }
}

/// Thread-safe memoizing scorer: compiles the mixed plan once per distinct
/// assignment and evaluates accuracy on (a capped prefix of) the held-out
/// split via the batched evaluator; logs every score for frontier
/// extraction.
///
/// The cache keys on the canonical [`MixedSpec::name`], so every phase —
/// uniform enumeration, greedy rounds, beam rounds, restarts — shares hits
/// on identical assignments. Scoring is a pure function of the assignment
/// (batched EMAC accuracy is bit-identical at any pool width; the cost
/// table replays `network_cost_ir` exactly), so concurrent evaluation can
/// never change a value, only the order values land — and
/// [`Evaluator::score_all`] merges in submission order, keeping the log
/// deterministic too.
struct Evaluator<'a> {
    ds: &'a Dataset,
    mlp: &'a Mlp,
    rows: usize,
    /// Pre-synthesized per-(layer, format) hardware costs.
    costs: CostTable,
    /// Candidate-level fan-out pool.
    pool: &'a WorkerPool,
    /// Width-1 pool pinning a fanned-out candidate's batches to its own
    /// thread (fan-outs must not nest — DESIGN.md §12's sharing rule).
    inline: WorkerPool,
    state: Mutex<EvalState>,
}

/// The evaluator's shared mutable state (one lock, never held while
/// compiling or evaluating).
struct EvalState {
    cache: HashMap<String, (f64, NetworkCost)>,
    log: Vec<ParetoPoint>,
}

impl Evaluator<'_> {
    /// Pure scoring: compile (or prefix-reuse from `base`) and evaluate.
    /// No lock is held in here.
    fn compute(&self, mixed: &MixedSpec, base: Option<&DeepPositron>, batch_pool: &WorkerPool) -> (f64, NetworkCost) {
        let dp = match base {
            Some(b) => b.recompile_mixed(self.mlp, mixed.clone()),
            None => DeepPositron::compile_mixed(self.mlp, mixed.clone()),
        };
        let accuracy = dp.accuracy_on_with(self.ds, Datapath::Emac, self.rows, batch_pool);
        (accuracy, self.costs.network(mixed))
    }

    /// Record a computed score (first write wins; scores are pure, so a
    /// lost race inserts an identical value) and return the cached entry.
    fn insert(&self, mixed: &MixedSpec, scored: (f64, NetworkCost)) -> (f64, NetworkCost) {
        let mut st = self.state.lock().expect("evaluator lock");
        let name = mixed.name();
        if let Some(&hit) = st.cache.get(&name) {
            return hit;
        }
        st.cache.insert(name, scored);
        st.log.push(ParetoPoint { mixed: mixed.clone(), accuracy: scored.0, cost: scored.1 });
        scored
    }

    /// Score one assignment (memoized; computes on this thread on a miss).
    fn score(&self, mixed: &MixedSpec) -> (f64, NetworkCost) {
        if let Some(&hit) = self.state.lock().expect("evaluator lock").cache.get(&mixed.name()) {
            MEMO_HITS.fetch_add(1, Ordering::Relaxed);
            return hit;
        }
        MEMO_MISSES.fetch_add(1, Ordering::Relaxed);
        let scored = self.compute(mixed, None, self.pool);
        self.insert(mixed, scored)
    }

    /// Warm the cache for a whole batch of `(assignment, reuse base)`
    /// pairs: distinct uncached assignments (first-occurrence order) fan
    /// out across the pool, results merge in that same order. Callers then
    /// read values back through [`Evaluator::score`] cache hits.
    fn score_all(&self, batch: &[(MixedSpec, Option<&DeepPositron>)]) {
        let todo: Vec<&(MixedSpec, Option<&DeepPositron>)> = {
            let st = self.state.lock().expect("evaluator lock");
            let mut seen = HashSet::new();
            batch
                .iter()
                .filter(|(m, _)| {
                    let name = m.name();
                    !st.cache.contains_key(&name) && seen.insert(name)
                })
                .collect()
        };
        MEMO_HITS.fetch_add((batch.len() - todo.len()) as u64, Ordering::Relaxed);
        MEMO_MISSES.fetch_add(todo.len() as u64, Ordering::Relaxed);
        if todo.is_empty() {
            return;
        }
        // Candidate-level fan-out pins each evaluation's batches inline;
        // a serial pool (or a single candidate) keeps batch-level fan-out.
        let batch_pool = if self.pool.threads() > 1 && todo.len() > 1 { &self.inline } else { self.pool };
        let jobs: Vec<_> = todo.iter().map(|(m, base)| move || self.compute(m, *base, batch_pool)).collect();
        let scored = self.pool.run_map(jobs);
        for ((m, _), s) in todo.iter().zip(scored) {
            self.insert(m, s);
        }
    }

    /// Distinct assignments evaluated at full search fidelity.
    fn evaluated(&self) -> usize {
        self.state.lock().expect("evaluator lock").cache.len()
    }
}

/// The acceptance-style default budget: hold accuracy within one point of
/// the best uniform 8-bit posit while minimizing network EDP — the
/// Cheetah-style "same accuracy, cheaper hardware" objective.
pub fn default_budget(ds: &Dataset, mlp: &Mlp, eval_rows: usize) -> Budget {
    let best = FormatSpec::sweep_family(8, "posit")
        .into_iter()
        .map(|spec| DeepPositron::compile(mlp, spec).accuracy_on(ds, Datapath::Emac, eval_rows))
        .fold(0.0f64, f64::max);
    Budget::MinAcc(best - 0.01)
}

/// Run the tuner: enumerate uniform candidates, descend per layer under
/// the budget, and report the plan + frontier. Deterministic in its
/// inputs (see the module docs for the argument).
pub fn tune(ds: &Dataset, mlp: &Mlp, cfg: &TuneConfig) -> TuneReport {
    let ir = mlp.ir();
    let nlayers = mlp.layers.len();
    let candidates: Vec<FormatSpec> = cfg.bits.clone().flat_map(FormatSpec::sweep).collect();
    assert!(!candidates.is_empty(), "empty candidate sweep");
    let owned_pool;
    let pool: &WorkerPool = match cfg.threads {
        Some(n) => {
            owned_pool = WorkerPool::new(n);
            &owned_pool
        }
        None => WorkerPool::global(),
    };
    // Every format the search can touch: the sweep alphabet plus the 8-bit
    // posit reference family (scored even when `bits` excludes 8).
    let mut alphabet = candidates.clone();
    for spec in FormatSpec::sweep_family(8, "posit") {
        if !alphabet.contains(&spec) {
            alphabet.push(spec);
        }
    }
    let ev = Evaluator {
        ds,
        mlp,
        rows: cfg.eval_rows,
        costs: CostTable::new(&ir, &alphabet),
        pool,
        inline: WorkerPool::new(1),
        state: Mutex::new(EvalState { cache: HashMap::new(), log: Vec::new() }),
    };

    // Phase 1: score every uniform candidate (plus the 8-bit posit
    // reference family, even when `bits` excludes 8), fanned out as one
    // batch. Pruning never touches this phase, so a pruned and an
    // unpruned run share the same start below.
    let mut uniforms: Vec<MixedSpec> = candidates.iter().map(|&c| MixedSpec::uniform(c, nlayers)).collect();
    for spec in FormatSpec::sweep_family(8, "posit") {
        let u = MixedSpec::uniform(spec, nlayers);
        if !uniforms.contains(&u) {
            uniforms.push(u);
        }
    }
    let uniform_batch: Vec<(MixedSpec, Option<&DeepPositron>)> = uniforms.iter().map(|u| (u.clone(), None)).collect();
    ev.score_all(&uniform_batch);
    let reference = FormatSpec::sweep_family(8, "posit")
        .into_iter()
        .map(|spec| {
            let mixed = MixedSpec::uniform(spec, nlayers);
            let (accuracy, cost) = ev.score(&mixed);
            ParetoPoint { mixed, accuracy, cost }
        })
        .max_by(|a, b| {
            a.accuracy
                .partial_cmp(&b.accuracy)
                .expect("accuracy is never NaN")
                .then(b.cost.edp_pj_ns.partial_cmp(&a.cost.edp_pj_ns).expect("EDP is never NaN"))
        })
        .expect("posit sweep is non-empty");

    // Phase 2: pick the start — best feasible uniform by the budget's
    // objective; if the budget is unattainable even among uniforms, the
    // closest uniform (most accurate for MinAcc, cheapest otherwise).
    let scored: Vec<(MixedSpec, f64, NetworkCost)> =
        uniforms.iter().map(|u| (u.clone(), ev.score(u))).map(|(u, (a, c))| (u, a, c)).collect();
    let by_key = |key: fn(&Budget, f64, &NetworkCost) -> (f64, f64), budget: &Budget| {
        move |x: &&(MixedSpec, f64, NetworkCost), y: &&(MixedSpec, f64, NetworkCost)| {
            key(budget, x.1, &x.2)
                .partial_cmp(&key(budget, y.1, &y.2))
                .expect("keys are never NaN")
                .then_with(|| x.0.name().cmp(&y.0.name()))
        }
    };
    let feasible_start = scored
        .iter()
        .filter(|(_, a, c)| cfg.budget.feasible(*a, c))
        .min_by(by_key(objective_key, &cfg.budget))
        .map(|(m, _, _)| m.clone());
    let start = feasible_start.clone().unwrap_or_else(|| {
        scored
            .iter()
            .min_by(by_key(closest_key, &cfg.budget))
            .map(|(m, _, _)| m.clone())
            .expect("uniform candidates are non-empty")
    });

    // Phase 2.5: sensitivity pre-pass from the chosen start — build the
    // per-layer bitwidth table on a cheap screening prefix and prune each
    // layer's candidate pool to the widths above its drop floor. The
    // start's own formats always survive (their drop is 0 at their own
    // width), so descent never loses its footing.
    let sensitivity = cfg
        .prune_drop
        .map(|drop| sensitivity::prepass(ds, mlp, &start, cfg.bits.clone(), drop, cfg.eval_rows, pool));
    let pools: Vec<Vec<FormatSpec>> = match &sensitivity {
        Some(table) => table.pools(&candidates),
        None => vec![candidates.clone(); nlayers],
    };
    // Observability: how many per-layer candidate formats pruning removed
    // from the descent's move generator (0 for an unpruned run).
    let removed: usize = pools.iter().map(|p| candidates.len() - p.len()).sum();
    EVALS_PRUNED.fetch_add(removed as u64, Ordering::Relaxed);

    // Phase 3: beam descent over single-layer reassignments. Converges
    // because the incumbent only ever moves to a strictly better feasible
    // key (or from infeasible to feasible once), and the evaluator
    // memoizes every visited assignment. Per round: compile each beam
    // state once, generate every surviving move in (state, layer,
    // candidate) order, warm the cache for the whole round in one fan-out
    // (each move recompiles only the ≤ 2 layers it touched), then rank —
    // scoring is pure and ranking reads cache hits in generation order, so
    // the round's outcome is independent of pool width.
    let mut incumbent = start.clone();
    let mut incumbent_feasible = feasible_start.is_some();
    let mut incumbent_key = {
        let (a, c) = ev.score(&incumbent);
        cfg.budget.key(a, &c)
    };
    let mut beam: Vec<MixedSpec> = vec![start];
    let mut rounds = 0usize;
    for _ in 0..cfg.max_rounds {
        let bases: Vec<DeepPositron> = beam.iter().map(|m| DeepPositron::compile_mixed(mlp, m.clone())).collect();
        let mut round: Vec<(MixedSpec, Option<&DeepPositron>)> = Vec::new();
        for (state, base) in beam.iter().zip(&bases) {
            for (li, pool_c) in pools.iter().enumerate() {
                for &c in pool_c {
                    if state.layers()[li] == c {
                        continue;
                    }
                    round.push((state.with_layer(li, c), Some(base)));
                }
            }
        }
        ev.score_all(&round);
        let mut next: Vec<((f64, f64), String, MixedSpec)> = Vec::new();
        for (cand, _) in round {
            let (accuracy, cost) = ev.score(&cand);
            if cfg.budget.feasible(accuracy, &cost) {
                next.push((cfg.budget.key(accuracy, &cost), cand.name(), cand));
            }
        }
        if next.is_empty() {
            break; // no feasible neighbor anywhere in the beam
        }
        next.sort_by(|a, b| a.0.partial_cmp(&b.0).expect("keys are never NaN").then_with(|| a.1.cmp(&b.1)));
        next.dedup_by(|a, b| a.2 == b.2);
        rounds += 1;
        let best_key = next[0].0;
        if incumbent_feasible && best_key >= incumbent_key {
            break; // converged: no feasible move improves the incumbent
        }
        incumbent = next[0].2.clone();
        incumbent_key = best_key;
        incumbent_feasible = true;
        beam = next.into_iter().take(cfg.beam).map(|(_, _, m)| m).collect();
    }

    let (accuracy, cost) = ev.score(&incumbent);
    let feasible = cfg.budget.feasible(accuracy, &cost);
    let dims = ir.dims();
    let pruned = sensitivity.as_ref().map(SensitivityTable::provenance);
    let plan = TunePlan { dataset: ds.name.clone(), dims, ir, assignment: incumbent, accuracy, cost, feasible, pruned };
    // Per-layer weight-quantization MSE under the chosen assignment (the
    // Fig. 5 metric, repurposed as the plan's explanation column; 0 for
    // weightless wiring layers, which quantize nothing).
    let layer_mse: Vec<f64> = plan
        .assignment
        .layers()
        .iter()
        .zip(&mlp.layers)
        .map(|(&s, l)| if l.w.is_empty() { 0.0 } else { quant::mse(s, &l.w) })
        .collect();
    let evaluated = ev.evaluated();
    let frontier = pareto_frontier(&ev.state.lock().expect("evaluator lock").log);
    TuneReport { plan, frontier, reference, budget: cfg.budget, evaluated, rounds, layer_mse, sensitivity }
}

/// Free-function form of [`Budget::key`] (so start selection can rank by
/// either objective with one comparator builder).
fn objective_key(budget: &Budget, accuracy: f64, cost: &NetworkCost) -> (f64, f64) {
    budget.key(accuracy, cost)
}

/// Ranking for an unattainable budget: how close an infeasible assignment
/// comes (lower = closer).
fn closest_key(budget: &Budget, accuracy: f64, cost: &NetworkCost) -> (f64, f64) {
    match *budget {
        Budget::MaxEdp(_) => (cost.edp_pj_ns, -accuracy),
        Budget::MaxLuts(_) => (cost.luts, -accuracy),
        Budget::MinAcc(_) => (-accuracy, cost.edp_pj_ns),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn budget_parse_round_trips() {
        assert_eq!(Budget::parse("min-acc=0.95"), Some(Budget::MinAcc(0.95)));
        assert_eq!(Budget::parse("max-edp=1.5e6"), Some(Budget::MaxEdp(1.5e6)));
        assert_eq!(Budget::parse("max-luts=40000"), Some(Budget::MaxLuts(40000.0)));
        assert_eq!(Budget::parse("min-acc"), None);
        assert_eq!(Budget::parse("max-watts=3"), None);
    }

    #[test]
    fn budget_keys_rank_as_documented() {
        let cheap = NetworkCost {
            luts: 10.0,
            ffs: 0.0,
            dsps: 0.0,
            energy_pj: 1.0,
            delay_ns: 1.0,
            edp_pj_ns: 1.0,
            max_quire_bits: 10,
        };
        let pricey = NetworkCost { edp_pj_ns: 9.0, luts: 90.0, ..cheap };
        // Accuracy budget: cheaper EDP wins at equal accuracy.
        assert!(Budget::MinAcc(0.5).key(0.9, &cheap) < Budget::MinAcc(0.5).key(0.9, &pricey));
        // Cost budget: higher accuracy wins even when pricier.
        assert!(Budget::MaxEdp(10.0).key(0.95, &pricey) < Budget::MaxEdp(10.0).key(0.9, &cheap));
    }

    #[test]
    fn plan_text_round_trips() {
        let assignment = MixedSpec::parse("posit8es1+float6we3+fixed5q3").unwrap();
        let ir = NetIr::dense(&[4, 10, 8, 3]);
        let cost = network_cost_ir(&assignment, &ir);
        let plan = TunePlan {
            dataset: "iris".into(),
            dims: ir.dims(),
            ir,
            assignment,
            accuracy: 0.9667,
            cost,
            feasible: true,
            pruned: None,
        };
        let parsed = TunePlan::parse(&plan.to_text()).expect("round trip");
        assert_eq!(parsed.dataset, plan.dataset);
        assert_eq!(parsed.dims, plan.dims);
        assert_eq!(parsed.ir, plan.ir);
        assert_eq!(parsed.assignment, plan.assignment);
        assert!((parsed.accuracy - plan.accuracy).abs() < 1e-9);
        assert_eq!(parsed.feasible, plan.feasible);
        assert_eq!(parsed.pruned, None);
        // Cost is recomputed, not stored: bit-equal to the cost model.
        assert_eq!(parsed.cost, plan.cost);
        // Pruning provenance rides through the codec verbatim (the value
        // itself may contain '='; only the FIRST '=' splits key/value).
        let prov = "sensitivity drop<=5.0% floors=6,5,5 screen_rows=48";
        let pruned_plan = TunePlan { pruned: Some(prov.to_string()), ..plan.clone() };
        assert!(pruned_plan.to_text().contains(&format!("pruned={prov}\n")));
        let parsed = TunePlan::parse(&pruned_plan.to_text()).expect("pruned round trip");
        assert_eq!(parsed.pruned.as_deref(), Some(prov));
        // Malformed inputs are rejected, not mis-parsed.
        assert!(TunePlan::parse("dataset=iris\n").is_none());
        assert!(TunePlan::parse(&plan.to_text().replace("posit8es1", "bogus9")).is_none());
        // Pre-IR plan files (no ir= line) still parse, as dense.
        let legacy = plan.to_text().lines().filter(|l| !l.starts_with("ir=")).collect::<Vec<_>>().join("\n");
        let parsed = TunePlan::parse(&legacy).expect("legacy plans parse");
        assert_eq!(parsed.ir, plan.ir);
        assert_eq!(parsed.cost, plan.cost);
    }

    #[test]
    fn conv_plan_text_round_trips_with_topology() {
        let ir = NetIr::parse("1x28x28:conv4k5x5s2+pool2s2+flatten+dense10").unwrap();
        let assignment = MixedSpec::parse("posit8es1+posit7es1+posit7es1+float8we4").unwrap();
        let cost = network_cost_ir(&assignment, &ir);
        let plan = TunePlan {
            dataset: "mnist".into(),
            dims: ir.dims(),
            ir: ir.clone(),
            assignment,
            accuracy: 0.91,
            cost,
            feasible: true,
            pruned: None,
        };
        let text = plan.to_text();
        assert!(text.contains("ir=1x28x28:conv4k5x5s2+pool2s2+flatten+dense10"), "{text}");
        let parsed = TunePlan::parse(&text).expect("conv round trip");
        assert_eq!(parsed.ir, ir);
        assert_eq!(parsed.cost, plan.cost);
        // A conv plan with a mangled topology line must not silently parse:
        // the inferred shapes no longer match the dims= widths.
        assert!(TunePlan::parse(&text.replace("conv4k5x5s2", "conv4k9x9s2")).is_none());
    }
}
