//! Offline stand-in for the `anyhow` crate (the build environment has no
//! crates.io access — DESIGN.md §Substitutions).
//!
//! Implements exactly the subset this workspace uses: [`Error`], [`Result`],
//! the [`anyhow!`] / [`bail!`] macros, and the [`Context`] extension trait.
//! Errors are flattened to display strings with a context chain; `{}` prints
//! the outermost message, `{:#}` prints the full `context: cause` chain, and
//! `{:?}` prints an anyhow-style "Caused by" report (what `fn main() ->
//! anyhow::Result<()>` shows on failure).

use std::fmt;

/// A string-backed error with a chain of context frames (outermost first).
pub struct Error {
    frames: Vec<String>,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { frames: vec![message.to_string()] }
    }

    fn push_context(mut self, frame: String) -> Error {
        self.frames.insert(0, frame);
        self
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.frames.join(": "))
        } else {
            write!(f, "{}", self.frames[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.frames[0])?;
        if self.frames.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for frame in &self.frames[1..] {
                write!(f, "\n    {frame}")?;
            }
        }
        Ok(())
    }
}

// `Error` deliberately does NOT implement `std::error::Error`, so this
// blanket conversion cannot overlap with the reflexive `From<Error>`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// `Result<T, anyhow::Error>` alias, matching the real crate.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait attaching context to failures (`Result`) or absences
/// (`Option`).
pub trait Context<T, E> {
    /// Wrap the error value with additional context.
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;

    /// Wrap the error value with lazily evaluated context.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T, E> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).push_context(context.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).push_context(f().to_string()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::result::Result<(), std::io::Error> {
        Err(std::io::Error::new(std::io::ErrorKind::NotFound, "missing"))
    }

    #[test]
    fn context_chains_render() {
        let e = io_err().with_context(|| "reading manifest".to_string()).unwrap_err();
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: missing");
        assert!(format!("{e:?}").contains("Caused by"));
    }

    #[test]
    fn macros_build_errors() {
        let x = 3;
        let e = anyhow!("bad value {x}");
        assert_eq!(format!("{e}"), "bad value 3");
        let e = anyhow!("bad {}: {}", "pair", 7);
        assert_eq!(format!("{e}"), "bad pair: 7");
        fn f() -> Result<()> {
            bail!("boom {}", 1)
        }
        assert_eq!(format!("{}", f().unwrap_err()), "boom 1");
    }

    #[test]
    fn option_context() {
        let none: Option<u8> = None;
        let e = none.context("absent").unwrap_err();
        assert_eq!(format!("{e}"), "absent");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse() -> Result<u32> {
            Ok("12x".parse::<u32>()?)
        }
        assert!(parse().is_err());
    }
}
