//! Offline stub of the `xla-rs` PJRT bindings.
//!
//! The real backend links the XLA C library and executes the AOT'd HLO
//! artifacts produced by `python/compile/aot.py`. This build environment has
//! neither crates.io access nor the XLA shared library, so this crate keeps
//! the exact API surface the runtime layer (`deep_positron::runtime`) calls
//! and reports PJRT as unavailable at the single entry point,
//! [`PjRtClient::cpu`]. Every caller in the workspace treats that error as
//! "fall back to the bit-exact Sim engine", so the full test suite and the
//! serving stack run without XLA. Swap this path dependency for the real
//! `xla` crate to light up the fast path; no workspace code changes.

use std::fmt;
use std::path::Path;

/// Error type mirroring `xla::Error` (display-only here).
#[derive(Debug, Clone)]
pub struct Error(String);

impl Error {
    fn unavailable() -> Error {
        Error(
            "PJRT backend unavailable: the vendored `xla` crate is an offline stub \
             (see rust/vendor/xla); engines fall back to Sim"
                .to_string(),
        )
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

/// Result alias matching the real bindings.
pub type Result<T> = std::result::Result<T, Error>;

/// Element types a [`Literal`] can carry.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for f64 {}

/// PJRT client handle. The stub's only constructor always fails.
pub struct PjRtClient;

impl PjRtClient {
    /// Create a CPU client. Always errors in the stub.
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable())
    }

    /// Backend platform name.
    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }
}

/// Parsed HLO module (never constructed by the stub).
pub struct HloModuleProto;

impl HloModuleProto {
    /// Parse an HLO text file. Always errors in the stub.
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto> {
        Err(Error::unavailable())
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation;

impl XlaComputation {
    /// Wrap a parsed module.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }

    /// Compile for a client. Always errors in the stub.
    pub fn compile(&self, _client: &PjRtClient) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable())
    }
}

/// A compiled executable (never constructed by the stub).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Execute with the given argument buffers. Always errors in the stub.
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable())
    }
}

/// A device-side buffer (never constructed by the stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    /// Copy the buffer back to a host literal. Always errors in the stub.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable())
    }
}

/// A host-side tensor literal. The stub keeps no data: literals are only
/// ever fed to [`PjRtLoadedExecutable::execute`], which errors first.
#[derive(Debug, Clone)]
pub struct Literal;

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(_data: &[T]) -> Literal {
        Literal
    }

    /// Scalar literal.
    pub fn scalar<T: NativeType>(_value: T) -> Literal {
        Literal
    }

    /// Reshape to the given dimensions.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal)
    }

    /// Copy out as a typed vector. Always errors in the stub.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(Error::unavailable())
    }

    /// Split a tuple literal. Always errors in the stub.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(Error::unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = match PjRtClient::cpu() {
            Err(e) => e,
            Ok(_) => panic!("stub must fail"),
        };
        assert!(err.to_string().contains("unavailable"));
    }

    #[test]
    fn literal_constructors_are_callable() {
        let l = Literal::vec1(&[1.0f64, 2.0]).reshape(&[2]).unwrap();
        assert!(l.to_vec::<f64>().is_err());
        let _ = Literal::scalar(0.5f32);
    }
}
